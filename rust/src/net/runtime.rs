//! Per-node socket runtime: mesh rendezvous, reader threads, and the
//! round pump that drives a `NodeStateMachine` over real TCP streams.
//!
//! The pump mirrors the virtual-time engine's delivery admission
//! exactly (`sim::World::pump`): per-peer FIFO inboxes iterated in key
//! order, `Sync` holding every message until the receiver's round
//! matches its stamp, `Async` handing over each FIFO head immediately.
//! That shared admission logic is what makes a sync net run
//! byte-for-byte *and* trajectory-identical to the sim for the same
//! spec and seed.
//!
//! Failure model: a peer that closes its stream without a `Bye` (crash,
//! kill, reset) surfaces as a typed [`CommError`] and maps onto the
//! PR-5 churn lifecycle — the edge is killed in the local
//! `TopologyView`, buffered frames drain as churn drops, and the
//! machine gets the same `on_topology` teardown a simulated
//! `DownKind::Churn` delivers.  A `Bye` is a clean finish: the edge
//! stays live and the runtime simply stops expecting traffic from it.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::algorithms::{NodeStateMachine, RoundPolicy};
use crate::comm::{directed_edge_index, CommError, Meter, Msg, Outbox};
use crate::graph::{Graph, TopologyView};
use crate::metrics::Mean;
use crate::sim::{LocalUpdate, Schedule};

use super::wire::{self, WireBody, WireMsg, HEADER_BYTES};

/// What a reader thread reports into the node's event channel.
pub(crate) enum NetEvent {
    /// A decoded payload from `peer`, carrying the sender's round stamp
    /// and the edge incarnation it was encoded for.
    Msg { peer: usize, round: usize, epoch: u32, msg: Msg },
    /// The peer sent `Bye`: it finished its rounds cleanly.
    PeerDone { peer: usize },
    /// The peer's stream died without a `Bye` — crash semantics.
    PeerLost { peer: usize, error: CommError },
}

/// One node's live connections after the mesh rendezvous.
pub(crate) struct Links {
    /// Write half per neighbor (the reader half is owned by the reader
    /// threads via `try_clone`).
    pub writers: BTreeMap<usize, TcpStream>,
    /// Merged event stream from all reader threads.
    pub rx: Receiver<NetEvent>,
    pub readers: Vec<JoinHandle<()>>,
}

/// Establish the full neighbor mesh for `node`: dial every neighbor
/// with a larger id, accept from every neighbor with a smaller id
/// (each undirected edge gets exactly one stream, opened by its lower
/// endpoint... the *smaller* id dials so the ordering is canonical).
/// Dials retry until `timeout` — peers may start later than us — and
/// every accepted stream must open with a `Hello` naming an expected
/// neighbor.
pub(crate) fn connect_mesh(
    node: usize,
    graph: &Graph,
    listener: TcpListener,
    peer_addrs: &[SocketAddr],
    meter: &Arc<Meter>,
    timeout: Duration,
) -> Result<Links> {
    let deadline = Instant::now() + timeout;
    let dial_to: Vec<usize> = graph
        .neighbors(node)
        .iter()
        .copied()
        .filter(|&j| j > node)
        .collect();
    let accept_from: BTreeSet<usize> = graph
        .neighbors(node)
        .iter()
        .copied()
        .filter(|&j| j < node)
        .collect();

    // Accept in a helper thread so dialing and accepting interleave —
    // sequencing them can deadlock on cyclic topologies.
    let expected = accept_from.clone();
    let acceptor = std::thread::spawn(move || -> Result<BTreeMap<usize, TcpStream>> {
        let mut got: BTreeMap<usize, TcpStream> = BTreeMap::new();
        listener
            .set_nonblocking(true)
            .context("listener set_nonblocking")?;
        while got.len() < expected.len() {
            if Instant::now() >= deadline {
                let missing: Vec<usize> = expected
                    .iter()
                    .filter(|j| !got.contains_key(j))
                    .copied()
                    .collect();
                bail!("node {node}: timed out accepting from {missing:?}");
            }
            let stream = match listener.accept() {
                Ok((s, _)) => s,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                    continue;
                }
                Err(e) => bail!("node {node}: accept failed: {e}"),
            };
            stream.set_nonblocking(false).context("accepted stream")?;
            // Bound the handshake read so a stray connection cannot
            // wedge the rendezvous.  Read unbuffered: a BufReader's
            // readahead could swallow round-0 bytes a fast dialer sends
            // right behind its Hello.
            stream
                .set_read_timeout(Some(Duration::from_secs(5)))
                .context("handshake read timeout")?;
            let hello = wire::read_message(&mut &stream)
                .map_err(|e| anyhow!("node {node}: handshake: {e}"))?
                .ok_or_else(|| {
                    anyhow!("node {node}: peer closed before Hello")
                })?;
            ensure!(
                matches!(hello.body, WireBody::Hello),
                "node {node}: expected Hello, got a data message"
            );
            ensure!(
                expected.contains(&hello.src) && !got.contains_key(&hello.src),
                "node {node}: unexpected Hello from {}",
                hello.src
            );
            stream.set_read_timeout(None).context("clear read timeout")?;
            got.insert(hello.src, stream);
        }
        Ok(got)
    });

    // Dial the larger-id neighbors, retrying while they come up.
    let mut dialed: BTreeMap<usize, TcpStream> = BTreeMap::new();
    for &j in &dial_to {
        let addr = peer_addrs[j];
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        bail!("node {node}: dialing {j} at {addr}: {e}");
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        };
        wire::write_message(&mut &stream, &WireMsg::hello(node))
            .map_err(|e| anyhow!("node {node}: Hello to {j}: {e}"))?;
        meter.record_header_overhead(node, HEADER_BYTES as u64);
        dialed.insert(j, stream);
    }

    let accepted = acceptor
        .join()
        .map_err(|_| anyhow!("node {node}: acceptor panicked"))??;

    let mut writers = BTreeMap::new();
    let (tx, rx) = channel::<NetEvent>();
    let mut readers = Vec::new();
    for (peer, stream) in accepted.into_iter().chain(dialed) {
        stream.set_nodelay(true).context("set_nodelay")?;
        let reader = stream
            .try_clone()
            .with_context(|| format!("node {node}: clone stream to {peer}"))?;
        let tx = tx.clone();
        readers.push(std::thread::spawn(move || {
            reader_loop(node, peer, reader, tx)
        }));
        writers.insert(peer, stream);
    }
    drop(tx); // rx disconnects once every reader thread exits
    Ok(Links { writers, rx, readers })
}

/// Decode frames off one stream into the shared event channel until
/// the peer finishes (Bye then EOF) or fails.  Per-stream TCP ordering
/// means a `PeerLost` is always this reader's final event, after every
/// message that actually arrived.
fn reader_loop(node: usize, peer: usize, stream: TcpStream,
               tx: Sender<NetEvent>) {
    let mut r = BufReader::new(stream);
    let mut clean = false;
    loop {
        match wire::read_message(&mut r) {
            Ok(Some(m)) => {
                if m.src != peer {
                    let _ = tx.send(NetEvent::PeerLost {
                        peer,
                        error: CommError::Corrupt {
                            detail: format!(
                                "stream from {peer} carried src {}",
                                m.src
                            ),
                        },
                    });
                    return;
                }
                match m.body {
                    WireBody::Payload(msg) => {
                        if tx
                            .send(NetEvent::Msg {
                                peer,
                                round: m.round,
                                epoch: m.epoch,
                                msg,
                            })
                            .is_err()
                        {
                            return; // runtime gone; nothing to report to
                        }
                    }
                    WireBody::Bye => {
                        clean = true;
                        let _ = tx.send(NetEvent::PeerDone { peer });
                    }
                    WireBody::Hello => {
                        let _ = tx.send(NetEvent::PeerLost {
                            peer,
                            error: CommError::Corrupt {
                                detail: format!(
                                    "mid-stream Hello from {peer}"
                                ),
                            },
                        });
                        return;
                    }
                }
            }
            Ok(None) => {
                // Clean EOF: crash semantics unless a Bye preceded it.
                if !clean {
                    let _ = tx.send(NetEvent::PeerLost {
                        peer,
                        error: CommError::Disconnected { node, peer },
                    });
                }
                return;
            }
            Err(e) => {
                if !clean {
                    let _ = tx.send(NetEvent::PeerLost { peer, error: e });
                }
                return;
            }
        }
    }
}

/// What one node's run produced (evals stream out via the callback).
pub(crate) struct NodeOutcome {
    pub max_staleness: usize,
    /// True when the run ended via the intentional kill hook.
    pub killed: bool,
}

/// The per-node engine: owns the sockets and drives one machine
/// through the schedule.
pub(crate) struct NetNodeRuntime {
    node: usize,
    graph: Arc<Graph>,
    view: TopologyView,
    policy: RoundPolicy,
    writers: BTreeMap<usize, TcpStream>,
    rx: Receiver<NetEvent>,
    readers: Vec<JoinHandle<()>>,
    meter: Arc<Meter>,
    /// Per-peer FIFO of undelivered `(round, epoch, msg)` — the same
    /// buffering the sim keeps per source.
    inbox: BTreeMap<usize, VecDeque<(usize, u32, Msg)>>,
    /// Peers whose streams died (edges already torn down).
    lost: BTreeSet<usize>,
    /// Peers that sent `Bye` (finished cleanly; edges stay live).
    done_peers: BTreeSet<usize>,
    /// Write failures observed mid-flush, pending the churn teardown
    /// (which needs the machine and is applied at the next safe point).
    pending_lost: Vec<(usize, CommError)>,
    stall_timeout: Duration,
    /// Cooperative abort: set when any sibling node in the deployment
    /// fails, so survivors stop waiting on a round that can never
    /// complete instead of riding out the full stall timeout.
    abort: Arc<AtomicBool>,
}

impl NetNodeRuntime {
    pub(crate) fn new(
        node: usize,
        graph: Arc<Graph>,
        links: Links,
        meter: Arc<Meter>,
        policy: RoundPolicy,
        stall_timeout: Duration,
        abort: Arc<AtomicBool>,
    ) -> NetNodeRuntime {
        let view = TopologyView::full(graph.edges().len());
        NetNodeRuntime {
            node,
            graph,
            view,
            policy,
            writers: links.writers,
            rx: links.rx,
            readers: links.readers,
            meter,
            inbox: BTreeMap::new(),
            lost: BTreeSet::new(),
            done_peers: BTreeSet::new(),
            pending_lost: Vec::new(),
            stall_timeout,
            abort,
        }
    }

    /// Drive the machine through every round of the schedule.
    /// `on_eval` receives `(epoch, accuracy, loss, train_loss)` at each
    /// eval boundary.  `kill_after_round` ends the process abruptly
    /// (no `Bye`) after that round's `round_end` — the fault-injection
    /// hook the churn tests use.
    pub(crate) fn run(
        mut self,
        machine: Box<dyn NodeStateMachine>,
        local: Box<dyn LocalUpdate>,
        w: Vec<f32>,
        sched: &Schedule,
        kill_after_round: Option<usize>,
        on_eval: &mut dyn FnMut(usize, f64, f64, f64) -> Result<()>,
    ) -> Result<NodeOutcome> {
        let res = self.run_inner(machine, local, w, sched, kill_after_round,
                                 on_eval);
        if res.is_err() {
            // Slam the streams so peers see EOF now instead of riding
            // out their stall timeout on a node that already gave up.
            self.close_streams();
        }
        res
    }

    fn run_inner(
        &mut self,
        mut machine: Box<dyn NodeStateMachine>,
        mut local: Box<dyn LocalUpdate>,
        mut w: Vec<f32>,
        sched: &Schedule,
        kill_after_round: Option<usize>,
        on_eval: &mut dyn FnMut(usize, f64, f64, f64) -> Result<()>,
    ) -> Result<NodeOutcome> {
        let zeros = vec![0.0f32; w.len()];
        let mut train_loss = Mean::default();
        for round in 0..sched.total_rounds() {
            // `zsum` and `alpha_deg` are both shared borrows of the
            // machine, so the dual sum feeds the local step directly —
            // no per-round copy of the d_pad-sized slice.
            let loss = match machine.zsum() {
                Some(z) => {
                    local.local_round(round, &mut w, z, machine.alpha_deg())?
                }
                None => local.local_round(round, &mut w, &zeros,
                                          machine.alpha_deg())?,
            };
            train_loss.add(loss);
            let mut out = Outbox::new();
            machine.round_begin(round, &self.view, &mut w, &mut out)?;
            self.flush(&mut out, round)?;
            self.settle_lost(machine.as_mut(), &mut w, round)?;
            self.exchange(machine.as_mut(), &mut w, round)?;
            machine.round_end(round, &self.view, &mut w)?;
            if kill_after_round == Some(round) {
                // Crash semantics: slam every stream shut with no Bye.
                // Peers must map the resulting EOF onto churn teardown.
                self.close_streams();
                return Ok(NodeOutcome {
                    max_staleness: machine.max_staleness_seen(),
                    killed: true,
                });
            }
            if let Some(&epoch) = sched.eval_rounds.get(&round) {
                let (acc, eloss) = local.evaluate(&w)?;
                on_eval(epoch, acc, eloss, train_loss.take())?;
            }
        }
        self.shutdown_clean(sched.total_rounds())?;
        Ok(NodeOutcome {
            max_staleness: machine.max_staleness_seen(),
            killed: false,
        })
    }

    /// Pump the exchange phase of `round` until the machine's policy
    /// gate opens — the socket equivalent of `sim::World::pump`, with
    /// `rx.recv_timeout` standing in for the event queue.
    fn exchange(&mut self, machine: &mut dyn NodeStateMachine,
                w: &mut [f32], round: usize) -> Result<()> {
        loop {
            // Drain everything the readers have queued so far.
            while let Ok(ev) = self.rx.try_recv() {
                self.handle_event(ev, machine, w, round)?;
            }
            self.deliver_admissible(machine, w, round)?;
            if machine.round_complete() {
                return Ok(());
            }
            if self.abort.load(Ordering::Relaxed) {
                bail!(
                    "node {}: aborting round {round}: a sibling node failed",
                    self.node
                );
            }
            // Block for the next event; a stall here means a peer
            // wedged without closing its socket.
            match self.rx.recv_timeout(self.stall_timeout) {
                Ok(ev) => self.handle_event(ev, machine, w, round)?,
                Err(RecvTimeoutError::Timeout) => bail!(
                    "node {}: round {round} stalled for {:?} waiting on \
                     peers (policy {})",
                    self.node,
                    self.stall_timeout,
                    self.policy.name()
                ),
                Err(RecvTimeoutError::Disconnected) => {
                    // Every reader exited; if the gate still won't open
                    // the protocol can never finish.
                    self.deliver_admissible(machine, w, round)?;
                    if machine.round_complete() {
                        return Ok(());
                    }
                    bail!(
                        "node {}: all peers closed with round {round} \
                         incomplete",
                        self.node
                    );
                }
            }
        }
    }

    /// Feed every currently-admissible buffered message to the machine,
    /// in peer-id order — the same deterministic order the sim uses.
    fn deliver_admissible(&mut self, machine: &mut dyn NodeStateMachine,
                          w: &mut [f32], round: usize) -> Result<()> {
        loop {
            let mut found: Option<usize> = None;
            for (&src, q) in self.inbox.iter() {
                if let Some(&(msg_round, _, _)) = q.front() {
                    match self.policy {
                        RoundPolicy::Sync => {
                            ensure!(
                                msg_round >= round,
                                "net: node {} holds a stale round-{msg_round} \
                                 message from {src} while in round {round}",
                                self.node
                            );
                            if msg_round == round {
                                found = Some(src);
                                break;
                            }
                        }
                        RoundPolicy::Async { .. } => {
                            found = Some(src);
                            break;
                        }
                    }
                }
            }
            let Some(src) = found else { return Ok(()) };
            let (msg_round, _, msg) = self
                .inbox
                .get_mut(&src)
                .and_then(|q| q.pop_front())
                .expect("front just observed");
            let mut out = Outbox::new();
            machine.on_message(msg_round, src, msg, &self.view, w, &mut out)?;
            self.flush(&mut out, round)?;
            self.settle_lost(machine, w, round)?;
        }
    }

    fn handle_event(&mut self, ev: NetEvent,
                    machine: &mut dyn NodeStateMachine, w: &mut [f32],
                    round: usize) -> Result<()> {
        match ev {
            NetEvent::Msg { peer, round: msg_round, epoch, msg } => {
                self.admit(peer, msg_round, epoch, msg);
            }
            NetEvent::PeerDone { peer } => {
                self.done_peers.insert(peer);
            }
            NetEvent::PeerLost { peer, error } => {
                self.on_peer_lost(peer, error, machine, w, round)?;
            }
        }
        Ok(())
    }

    /// Buffer an arrived message, applying the same incarnation check
    /// the sim applies at delivery: traffic for a dead or reborn edge
    /// drains as a typed churn drop, never reaching the machine.
    fn admit(&mut self, peer: usize, round: usize, epoch: u32, msg: Msg) {
        let bytes = msg.wire_bytes() as u64;
        if self.lost.contains(&peer) {
            self.meter.record_churn_drop(bytes);
            return;
        }
        match self.graph.edge_index(self.node, peer) {
            Some(edge) => {
                let life = self.view.edge_life(edge);
                if !life.live || life.epoch != epoch {
                    self.meter.record_churn_drop(bytes);
                    return;
                }
            }
            None => {
                // Cannot happen post-handshake; drop defensively.
                self.meter.record_churn_drop(bytes);
                return;
            }
        }
        self.inbox
            .entry(peer)
            .or_default()
            .push_back((round, epoch, msg));
    }

    /// Map a dead stream onto the churn lifecycle: kill the edge, drain
    /// buffered frames as churn drops, and give the machine the same
    /// `on_topology` teardown a simulated churn event delivers.
    /// Idempotent; a peer that already said `Bye` finished cleanly and
    /// needs no teardown.
    fn on_peer_lost(&mut self, peer: usize, _error: CommError,
                    machine: &mut dyn NodeStateMachine, w: &mut [f32],
                    round: usize) -> Result<()> {
        if self.done_peers.contains(&peer) || !self.lost.insert(peer) {
            return Ok(());
        }
        if let Some(edge) = self.graph.edge_index(self.node, peer) {
            if self.view.is_live(edge) {
                self.view.kill_edge(edge);
                self.meter.record_edge_churn();
            }
        }
        if let Some(q) = self.inbox.get_mut(&peer) {
            for (_, _, msg) in q.drain(..) {
                self.meter.record_churn_drop(msg.wire_bytes() as u64);
            }
        }
        let mut out = Outbox::new();
        machine.on_topology(&self.view, w, &mut out)?;
        self.flush(&mut out, round)?;
        Ok(())
    }

    /// Apply churn teardowns queued by write failures.  Teardown can
    /// queue further sends (none of the current protocols do), whose
    /// failures queue further teardowns — loop to a fixed point.
    fn settle_lost(&mut self, machine: &mut dyn NodeStateMachine,
                   w: &mut [f32], round: usize) -> Result<()> {
        while let Some((peer, error)) = self.pending_lost.pop() {
            self.on_peer_lost(peer, error, machine, w, round)?;
        }
        Ok(())
    }

    fn flush(&mut self, out: &mut Outbox, round: usize) -> Result<()> {
        let queued: Vec<(usize, Msg)> = out.drain().collect();
        for (to, msg) in queued {
            self.send(to, round, msg)?;
        }
        Ok(())
    }

    /// Send one payload, mirroring the sim courier's accounting: the
    /// payload is metered (totals and the directed-edge slot) *before*
    /// the liveness check, so byte counts stay engine-identical; a dead
    /// edge turns the send into a churn drop; a write failure marks the
    /// peer lost for the next `settle_lost`.
    fn send(&mut self, to: usize, round: usize, msg: Msg) -> Result<()> {
        let edge = self
            .graph
            .edge_index(self.node, to)
            .ok_or(CommError::NoEdge { node: self.node, peer: to })?;
        let bytes = msg.wire_bytes();
        self.meter.record_send(self.node, bytes);
        self.meter
            .record_edge_send(directed_edge_index(edge, self.node, to),
                              bytes as u64);
        let life = self.view.edge_life(edge);
        if !life.live {
            self.meter.record_churn_drop(bytes as u64);
            return Ok(());
        }
        let wm = WireMsg {
            src: self.node,
            round,
            epoch: life.epoch,
            body: WireBody::Payload(msg),
        };
        let stream = self
            .writers
            .get(&to)
            .ok_or(CommError::NoEdge { node: self.node, peer: to })?;
        match wire::write_message(&mut &*stream, &wm) {
            Ok(written) => {
                self.meter.record_header_overhead(
                    self.node,
                    (written - bytes) as u64,
                );
                Ok(())
            }
            Err(e @ (CommError::Io { .. } | CommError::Disconnected { .. })) => {
                // The transmission left this node (metered); the peer is
                // gone.  Same churn-drop semantics as a dead edge, plus
                // the deferred teardown.
                self.meter.record_churn_drop(bytes as u64);
                self.pending_lost.push((to, e));
                Ok(())
            }
            Err(e) => Err(e.into()),
        }
    }

    /// Clean shutdown: announce `Bye` on every surviving stream, then
    /// linger until each neighbor has finished or failed before closing
    /// — closing early would RST data a lagging peer still needs.
    fn shutdown_clean(&mut self, final_round: usize) -> Result<()> {
        let peers: Vec<usize> = self.writers.keys().copied().collect();
        for &peer in &peers {
            if self.lost.contains(&peer) {
                continue;
            }
            let stream = &self.writers[&peer];
            match wire::write_message(&mut &*stream,
                                      &WireMsg::bye(self.node, final_round)) {
                Ok(written) => self
                    .meter
                    .record_header_overhead(self.node, written as u64),
                Err(_) => {
                    // The peer vanished between its last message and our
                    // Bye; nothing left to tear down — we're done.
                    self.lost.insert(peer);
                }
            }
        }
        let deadline = Instant::now() + self.stall_timeout;
        loop {
            let all_accounted = peers
                .iter()
                .all(|p| self.done_peers.contains(p) || self.lost.contains(p));
            if all_accounted {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break; // close anyway; the deployment is wedged
            }
            match self.rx.recv_timeout(deadline - now) {
                // Late traffic from lagging async peers: already
                // consumed for our purposes; discard without touching
                // the churn counters (nothing failed).
                Ok(NetEvent::Msg { .. }) => {}
                Ok(NetEvent::PeerDone { peer }) => {
                    self.done_peers.insert(peer);
                }
                Ok(NetEvent::PeerLost { peer, .. }) => {
                    // Post-completion loss: no machine left to notify,
                    // but the edge still churns for the report.
                    if self.done_peers.contains(&peer)
                        || !self.lost.insert(peer)
                    {
                        continue;
                    }
                    if let Some(edge) =
                        self.graph.edge_index(self.node, peer)
                    {
                        if self.view.is_live(edge) {
                            self.view.kill_edge(edge);
                            self.meter.record_edge_churn();
                        }
                    }
                }
                Err(_) => break,
            }
        }
        self.close_streams();
        Ok(())
    }

    /// Shut every stream down (both halves — the reader threads hold
    /// fd clones, so a plain drop would never send FIN) and join the
    /// readers.
    fn close_streams(&mut self) {
        for stream in self.writers.values() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}
