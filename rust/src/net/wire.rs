//! The length-prefixed binary wire protocol of the net engine.
//!
//! Every message is one fixed 24-byte header followed by the payload:
//!
//! ```text
//! offset  size  field        encoding
//! ------  ----  -----------  --------------------------------------
//!      0     4  magic        0x4345434C ("CECL"), little-endian u32
//!      4     2  version      protocol version, currently 1 (LE u16)
//!      6     1  kind         message kind (see below)
//!      7     1  reserved     must be 0
//!      8     4  src          sender node id (LE u32)
//!     12     4  epoch        edge incarnation at send time (LE u32)
//!     16     4  round        sender's round clock (LE u32)
//!     20     4  payload_len  payload bytes that follow (LE u32)
//! ```
//!
//! Kinds: `0 = hello` (connection handshake, empty payload), `1 =
//! dense` (f32 LE array), `2 = frame` (raw codec `Frame` buffer), `3 =
//! scalar` (one f64 LE), `4 = bye` (clean shutdown, empty payload).
//!
//! Framing rules: `payload_len` is exactly `Msg::wire_bytes()` for
//! every data kind, so the payload accounting on the socket is
//! byte-identical to the in-process engines; the 24 header bytes are
//! metered separately (`Meter::record_header_overhead`).  A reader
//! that sees a bad magic, an unknown version or kind, a nonzero
//! reserved byte, or an implausible length rejects the stream as
//! [`CommError::Corrupt`] — it never resynchronizes.  `Msg::Sparse`
//! (PJRT interop) never crosses this wire and is rejected at encode
//! time.  EOF exactly on a message boundary is a clean close; EOF
//! mid-message is `Corrupt`; any other socket failure is
//! [`CommError::Io`].

use std::io::{ErrorKind, Read, Write};

use crate::comm::{CommError, Msg};
use crate::compress::Frame;

/// Fixed header size; the per-message framing overhead the net engine
/// meters apart from payload bytes.
pub const HEADER_BYTES: usize = 24;

/// "CECL" as a little-endian u32.
pub const MAGIC: u32 = 0x4345_434C;

/// Current protocol version.
pub const VERSION: u16 = 1;

/// Sanity cap on `payload_len` — far above any real frame (the models
/// here are a few KB), small enough that a corrupt length can never
/// drive an allocation bomb.
pub const MAX_PAYLOAD_BYTES: usize = 16 << 20;

const KIND_HELLO: u8 = 0;
const KIND_DENSE: u8 = 1;
const KIND_FRAME: u8 = 2;
const KIND_SCALAR: u8 = 3;
const KIND_BYE: u8 = 4;

/// A decoded message body.
#[derive(Debug, Clone)]
pub enum WireBody {
    /// Connection handshake: identifies the dialer to the acceptor.
    Hello,
    /// Clean shutdown: the peer has finished its rounds and will send
    /// nothing more.  Distinguishes a finished peer from a crashed one
    /// (bare EOF), which maps onto the churn lifecycle.
    Bye,
    /// An algorithm payload, byte-identical to the in-process `Msg`.
    Payload(Msg),
}

/// One decoded wire message.
#[derive(Debug, Clone)]
pub struct WireMsg {
    pub src: usize,
    pub round: usize,
    pub epoch: u32,
    pub body: WireBody,
}

impl WireMsg {
    pub fn hello(src: usize) -> WireMsg {
        WireMsg { src, round: 0, epoch: 0, body: WireBody::Hello }
    }

    pub fn bye(src: usize, round: usize) -> WireMsg {
        WireMsg { src, round, epoch: 0, body: WireBody::Bye }
    }
}

fn io_err(detail: String) -> CommError {
    CommError::Io { detail }
}

fn corrupt(detail: String) -> CommError {
    CommError::Corrupt { detail }
}

// Checked little-endian field readers.  Every offset used below is a
// compile-time constant inside a fixed-size header, but the parse path
// carries a no-panic contract on arbitrary peer bytes (`repro lint`
// enforces it), so each read is bounds-checked and surfaces a typed
// `Corrupt` instead of slicing.

fn le_u16(b: &[u8], off: usize) -> Result<u16, CommError> {
    let arr: [u8; 2] = b
        .get(off..off + 2)
        .and_then(|s| s.try_into().ok())
        .ok_or_else(|| corrupt(format!("truncated u16 at offset {off}")))?;
    Ok(u16::from_le_bytes(arr))
}

fn le_u32(b: &[u8], off: usize) -> Result<u32, CommError> {
    let arr: [u8; 4] = b
        .get(off..off + 4)
        .and_then(|s| s.try_into().ok())
        .ok_or_else(|| corrupt(format!("truncated u32 at offset {off}")))?;
    Ok(u32::from_le_bytes(arr))
}

fn le_f32(b: &[u8], off: usize) -> Result<f32, CommError> {
    let arr: [u8; 4] = b
        .get(off..off + 4)
        .and_then(|s| s.try_into().ok())
        .ok_or_else(|| corrupt(format!("truncated f32 at offset {off}")))?;
    Ok(f32::from_le_bytes(arr))
}

fn le_f64(b: &[u8], off: usize) -> Result<f64, CommError> {
    let arr: [u8; 8] = b
        .get(off..off + 8)
        .and_then(|s| s.try_into().ok())
        .ok_or_else(|| corrupt(format!("truncated f64 at offset {off}")))?;
    Ok(f64::from_le_bytes(arr))
}

fn byte_at(b: &[u8], off: usize) -> Result<u8, CommError> {
    b.get(off)
        .copied()
        .ok_or_else(|| corrupt(format!("truncated byte at offset {off}")))
}

/// Serialize header + payload into one buffer (a single `write_all`, so
/// the kernel never sees a torn message from this side).
pub fn encode_message(msg: &WireMsg) -> Result<Vec<u8>, CommError> {
    let (kind, payload): (u8, Vec<u8>) = match &msg.body {
        WireBody::Hello => (KIND_HELLO, Vec::new()),
        WireBody::Bye => (KIND_BYE, Vec::new()),
        WireBody::Payload(Msg::Dense(v)) => {
            let mut buf = Vec::with_capacity(4 * v.len());
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            (KIND_DENSE, buf)
        }
        WireBody::Payload(Msg::Frame(f)) => (KIND_FRAME, f.bytes().to_vec()),
        WireBody::Payload(Msg::Scalar(s)) => {
            (KIND_SCALAR, s.to_le_bytes().to_vec())
        }
        WireBody::Payload(other @ Msg::Sparse(_)) => {
            return Err(CommError::WrongPayload {
                expected: "socket-encodable",
                got: other.kind(),
            });
        }
    };
    if payload.len() > MAX_PAYLOAD_BYTES {
        return Err(corrupt(format!(
            "payload of {} bytes exceeds the wire cap",
            payload.len()
        )));
    }
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.push(kind);
    buf.push(0); // reserved
    buf.extend_from_slice(&(msg.src as u32).to_le_bytes());
    buf.extend_from_slice(&msg.epoch.to_le_bytes());
    buf.extend_from_slice(&(msg.round as u32).to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&payload);
    Ok(buf)
}

/// Encode and write one message.  Returns the bytes written (header +
/// payload), so callers can meter framing overhead as
/// `written - msg.wire_bytes()`.
pub fn write_message(w: &mut impl Write, msg: &WireMsg)
                     -> Result<usize, CommError> {
    let buf = encode_message(msg)?;
    w.write_all(&buf)
        .map_err(|e| io_err(format!("write to peer failed: {e}")))?;
    Ok(buf.len())
}

/// Read one message.  `Ok(None)` is a clean EOF exactly on a message
/// boundary; mid-message EOF is `Corrupt`; other socket failures are
/// `Io`.
pub fn read_message(r: &mut impl Read) -> Result<Option<WireMsg>, CommError> {
    let mut header = [0u8; HEADER_BYTES];
    let mut got = 0usize;
    while got < HEADER_BYTES {
        // det:allow(index-decode): `got < HEADER_BYTES` is the loop
        // condition, so the range start is always in bounds.
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None); // clean close between messages
                }
                return Err(corrupt(format!(
                    "EOF after {got} of {HEADER_BYTES} header bytes"
                )));
            }
            Ok(k) => got += k,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(io_err(format!("read failed: {e}"))),
        }
    }
    let magic = le_u32(&header, 0)?;
    if magic != MAGIC {
        return Err(corrupt(format!("bad magic {magic:#010x}")));
    }
    let version = le_u16(&header, 4)?;
    if version != VERSION {
        return Err(corrupt(format!(
            "unsupported protocol version {version} (this side speaks \
             {VERSION})"
        )));
    }
    let kind = byte_at(&header, 6)?;
    let reserved = byte_at(&header, 7)?;
    if reserved != 0 {
        return Err(corrupt(format!("nonzero reserved byte {reserved}")));
    }
    let src = le_u32(&header, 8)? as usize;
    let epoch = le_u32(&header, 12)?;
    let round = le_u32(&header, 16)? as usize;
    let len = le_u32(&header, 20)? as usize;
    if len > MAX_PAYLOAD_BYTES {
        return Err(corrupt(format!("payload length {len} exceeds cap")));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == ErrorKind::UnexpectedEof {
            corrupt(format!("EOF inside a {len}-byte payload"))
        } else {
            io_err(format!("read failed: {e}"))
        }
    })?;
    let body = match kind {
        KIND_HELLO | KIND_BYE => {
            if len != 0 {
                return Err(corrupt(format!(
                    "control message (kind {kind}) with {len}-byte payload"
                )));
            }
            if kind == KIND_HELLO { WireBody::Hello } else { WireBody::Bye }
        }
        KIND_DENSE => {
            if len % 4 != 0 {
                return Err(corrupt(format!(
                    "dense payload of {len} bytes is not f32-aligned"
                )));
            }
            let mut v = Vec::with_capacity(len / 4);
            for k in 0..len / 4 {
                v.push(le_f32(&payload, 4 * k)?);
            }
            WireBody::Payload(Msg::Dense(v))
        }
        KIND_FRAME => WireBody::Payload(Msg::Frame(Frame::new(payload))),
        KIND_SCALAR => {
            if len != 8 {
                return Err(corrupt(format!(
                    "scalar payload of {len} bytes (want 8)"
                )));
            }
            let s = le_f64(&payload, 0)?;
            WireBody::Payload(Msg::Scalar(s))
        }
        other => return Err(corrupt(format!("unknown message kind {other}"))),
    };
    Ok(Some(WireMsg { src, round, epoch, body }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: &WireMsg) -> WireMsg {
        let buf = encode_message(msg).unwrap();
        let mut cursor = &buf[..];
        let got = read_message(&mut cursor).unwrap().unwrap();
        // Exactly one message, nothing left over.
        assert!(read_message(&mut cursor).unwrap().is_none());
        got
    }

    #[test]
    fn header_is_24_bytes_and_payload_len_is_wire_bytes() {
        for msg in [
            Msg::Dense(vec![1.0, -2.5, 3.0]),
            Msg::Frame(Frame::new(vec![7u8; 13])),
            Msg::Scalar(0.25),
        ] {
            let want = msg.wire_bytes();
            let wm = WireMsg { src: 3, round: 9, epoch: 2,
                               body: WireBody::Payload(msg) };
            let buf = encode_message(&wm).unwrap();
            assert_eq!(buf.len(), HEADER_BYTES + want);
        }
        assert_eq!(encode_message(&WireMsg::hello(0)).unwrap().len(),
                   HEADER_BYTES);
        assert_eq!(encode_message(&WireMsg::bye(0, 5)).unwrap().len(),
                   HEADER_BYTES);
    }

    #[test]
    fn payloads_round_trip_bit_exactly() {
        let wm = WireMsg {
            src: 7,
            round: 123,
            epoch: 4,
            body: WireBody::Payload(Msg::Dense(vec![1.5, -0.0, f32::MIN])),
        };
        let got = round_trip(&wm);
        assert_eq!(got.src, 7);
        assert_eq!(got.round, 123);
        assert_eq!(got.epoch, 4);
        match got.body {
            WireBody::Payload(Msg::Dense(v)) => {
                assert_eq!(v.len(), 3);
                assert_eq!(v[0].to_bits(), 1.5f32.to_bits());
                assert_eq!(v[1].to_bits(), (-0.0f32).to_bits());
                assert_eq!(v[2].to_bits(), f32::MIN.to_bits());
            }
            other => panic!("wrong body: {other:?}"),
        }

        let frame_bytes: Vec<u8> = (0..=255).collect();
        let wm = WireMsg {
            src: 0,
            round: 0,
            epoch: 0,
            body: WireBody::Payload(Msg::Frame(Frame::new(frame_bytes.clone()))),
        };
        match round_trip(&wm).body {
            WireBody::Payload(Msg::Frame(f)) => {
                assert_eq!(f.bytes(), &frame_bytes[..]);
            }
            other => panic!("wrong body: {other:?}"),
        }

        let wm = WireMsg {
            src: 1,
            round: 2,
            epoch: 0,
            body: WireBody::Payload(Msg::Scalar(-1.25e-5)),
        };
        match round_trip(&wm).body {
            WireBody::Payload(Msg::Scalar(s)) => {
                assert_eq!(s.to_bits(), (-1.25e-5f64).to_bits());
            }
            other => panic!("wrong body: {other:?}"),
        }

        assert!(matches!(round_trip(&WireMsg::hello(5)).body, WireBody::Hello));
        assert!(matches!(round_trip(&WireMsg::bye(5, 9)).body, WireBody::Bye));
    }

    #[test]
    fn sparse_payloads_never_cross_the_wire() {
        let coo = crate::compress::CooVec::gather(&[1.0, 2.0], &[0]);
        let wm = WireMsg {
            src: 0,
            round: 0,
            epoch: 0,
            body: WireBody::Payload(Msg::Sparse(coo)),
        };
        let err = encode_message(&wm).unwrap_err();
        assert_eq!(
            err,
            CommError::WrongPayload {
                expected: "socket-encodable",
                got: "sparse"
            }
        );
    }

    #[test]
    fn corrupt_streams_are_typed_errors() {
        let good = encode_message(&WireMsg {
            src: 1,
            round: 1,
            epoch: 0,
            body: WireBody::Payload(Msg::Scalar(1.0)),
        })
        .unwrap();

        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        let err = read_message(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        // Future protocol version.
        let mut bad = good.clone();
        bad[4..6].copy_from_slice(&99u16.to_le_bytes());
        let err = read_message(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        // Unknown kind.
        let mut bad = good.clone();
        bad[6] = 200;
        let err = read_message(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("kind"), "{err}");

        // Nonzero reserved byte.
        let mut bad = good.clone();
        bad[7] = 1;
        let err = read_message(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("reserved"), "{err}");

        // Implausible payload length.
        let mut bad = good.clone();
        bad[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_message(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");

        // Truncation mid-header and mid-payload: corrupt, not clean EOF.
        for cut in [3, HEADER_BYTES - 1, good.len() - 1] {
            let err = read_message(&mut &good[..cut]).unwrap_err();
            assert!(matches!(err, CommError::Corrupt { .. }), "cut {cut}: {err}");
        }

        // Misaligned dense payload.
        let dense = encode_message(&WireMsg {
            src: 0,
            round: 0,
            epoch: 0,
            body: WireBody::Payload(Msg::Dense(vec![1.0, 2.0])),
        })
        .unwrap();
        let mut bad = dense.clone();
        bad[20..24].copy_from_slice(&7u32.to_le_bytes());
        let err = read_message(&mut &bad[..HEADER_BYTES + 7]).unwrap_err();
        assert!(err.to_string().contains("aligned"), "{err}");
    }

    #[test]
    fn back_to_back_messages_parse_in_order() {
        let mut buf = Vec::new();
        buf.extend(encode_message(&WireMsg::hello(2)).unwrap());
        buf.extend(
            encode_message(&WireMsg {
                src: 2,
                round: 1,
                epoch: 0,
                body: WireBody::Payload(Msg::Frame(Frame::new(vec![9; 4]))),
            })
            .unwrap(),
        );
        buf.extend(encode_message(&WireMsg::bye(2, 1)).unwrap());
        let mut cursor = &buf[..];
        assert!(matches!(
            read_message(&mut cursor).unwrap().unwrap().body,
            WireBody::Hello
        ));
        let m = read_message(&mut cursor).unwrap().unwrap();
        assert_eq!(m.round, 1);
        assert!(matches!(m.body, WireBody::Payload(Msg::Frame(_))));
        assert!(matches!(
            read_message(&mut cursor).unwrap().unwrap().body,
            WireBody::Bye
        ));
        assert!(read_message(&mut cursor).unwrap().is_none());
    }
}
