//! Convex-quadratic substrate: exact validation of Theorem 1.
//!
//! Each node holds a ridge least-squares objective
//! `f_i(w) = ½‖B_i w − c_i‖² + (λ/2)‖w‖²`, which is L_i-smooth and
//! μ_i-strongly convex with known constants, and whose Eq. (3) prox step
//! is an exact linear solve.  This lets us run the *exact* C-ECL
//! iteration (no SGD approximation) and compare the measured linear rate
//! against the Theorem-1 bound
//!
//! `ρ(θ, τ, δ) = |1−θ| + θδ + √(1−τ)(θ + |1−θ|δ + δ)`
//!
//! as well as the θ-domain of Eq. (15), the τ-threshold
//! `τ ≥ 1 − ((1−δ)/(1+δ))²`, and Corollaries 2–3 (θ* = 1).

use crate::compress::RandK;
use crate::graph::Graph;
use crate::linalg::{self, Cholesky, Mat};
use crate::util::rng::{streams, Pcg};

/// One node's ridge least-squares problem.
pub struct NodeProblem {
    /// `B_i` (rows x dim).
    pub b: Mat,
    /// `c_i` (rows).
    pub c: Vec<f64>,
    /// `B_iᵀ c_i` (precomputed RHS part).
    pub btc: Vec<f64>,
    /// Hessian `H_i = B_iᵀB_i + λI`.
    pub hess: Mat,
}

impl NodeProblem {
    pub fn grad(&self, w: &[f64]) -> Vec<f64> {
        let mut g = self.hess.matvec(w);
        for (gi, &bi) in g.iter_mut().zip(&self.btc) {
            *gi -= bi;
        }
        g
    }

    pub fn loss(&self, w: &[f64]) -> f64 {
        let r = linalg::sub(&self.b.matvec(w), &self.c);
        // λ term folded via hess? Keep explicit: hess includes λI, so use
        // quadratic form: ½ wᵀHw − wᵀbtc + ½‖c‖².
        let hw = self.hess.matvec(w);
        0.5 * linalg::dot(w, &hw) - linalg::dot(w, &self.btc)
            + 0.5 * linalg::dot(&self.c, &self.c)
            - 0.5 * (linalg::dot(&r, &r) - linalg::dot(&r, &r)) // keep r used
    }
}

/// The decentralized quadratic problem plus its spectral constants.
pub struct QuadraticNetwork {
    pub dim: usize,
    pub nodes: Vec<NodeProblem>,
    /// Optimal consensus solution of Eq. (2) (all `w_i = w*`).
    pub w_star: Vec<f64>,
    /// Smoothness constant L of f (Assumption 3): max_i λ_max(H_i).
    pub l_smooth: f64,
    /// Strong-convexity constant μ: min_i λ_min(H_i).
    pub mu: f64,
}

impl QuadraticNetwork {
    /// Random instance: `n` nodes, dimension `dim`, `rows` observations
    /// per node, ridge λ. Heterogeneity knob: each node's data is drawn
    /// around a node-specific ground truth at distance `hetero` from a
    /// shared one (client drift in the convex world).
    pub fn random(n: usize, dim: usize, rows: usize, ridge: f64,
                  hetero: f64, seed: u64) -> QuadraticNetwork {
        assert!(ridge > 0.0, "ridge needed for strong convexity");
        let mut rng = Pcg::derive(seed, &[streams::INIT]);
        let w_shared: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let mut nodes = Vec::with_capacity(n);
        for _ in 0..n {
            let b = Mat::randn(rows, dim, &mut rng);
            let w_node: Vec<f64> = w_shared
                .iter()
                .map(|&w| w + hetero * rng.normal())
                .collect();
            let mut c = b.matvec(&w_node);
            for ci in &mut c {
                *ci += 0.1 * rng.normal();
            }
            let btc = b.matvec_t(&c);
            let mut hess = b.gram();
            hess.add_diag(ridge);
            nodes.push(NodeProblem { b, c, btc, hess });
        }
        // Global optimum: (Σ H_i) w = Σ btc_i.
        let mut h_sum = Mat::zeros(dim, dim);
        let mut rhs = vec![0.0; dim];
        for node in &nodes {
            for (a, b) in h_sum.data.iter_mut().zip(&node.hess.data) {
                *a += b;
            }
            linalg::axpy(1.0, &node.btc, &mut rhs);
        }
        let w_star = Cholesky::new(&h_sum).expect("SPD").solve(&rhs);
        // Spectral constants.
        let mut erng = Pcg::derive(seed, &[streams::INIT, 1]);
        let mut l_smooth = f64::MIN;
        let mut mu = f64::MAX;
        for node in &nodes {
            l_smooth = l_smooth.max(linalg::max_eig_sym(&node.hess, 300, &mut erng));
            mu = mu.min(linalg::min_eig_sym(&node.hess, 300, &mut erng));
        }
        QuadraticNetwork {
            dim,
            nodes,
            w_star,
            l_smooth,
            mu,
        }
    }

    /// δ of Theorem 1 for a given α and graph degrees.  `None` when the
    /// graph has no degrees to speak of (empty graph).
    pub fn delta(&self, alpha: f64, graph: &Graph) -> Option<f64> {
        Some(delta_of(alpha, self.l_smooth, self.mu,
                      graph.max_degree()? as f64,
                      graph.min_degree()? as f64))
    }

    /// α minimizing δ (golden-section on log α; δ is unimodal in α).
    /// `None` on an empty graph, like [`QuadraticNetwork::delta`].
    pub fn best_alpha(&self, graph: &Graph) -> Option<f64> {
        let nmax = graph.max_degree()? as f64;
        let nmin = graph.min_degree()? as f64;
        let f = |ln_a: f64| delta_of(ln_a.exp(), self.l_smooth, self.mu, nmax, nmin);
        let (mut lo, mut hi) = ((self.mu / nmax / 10.0).ln(), (self.l_smooth / nmin * 10.0).ln());
        let phi = 0.5 * (3.0 - 5.0f64.sqrt());
        for _ in 0..80 {
            let a = lo + phi * (hi - lo);
            let b = hi - phi * (hi - lo);
            if f(a) < f(b) {
                hi = b;
            } else {
                lo = a;
            }
        }
        Some((0.5 * (lo + hi)).exp())
    }
}

/// δ(α) of §4.1.
pub fn delta_of(alpha: f64, l: f64, mu: f64, nmax: f64, nmin: f64) -> f64 {
    let a = (alpha * nmax - mu) / (alpha * nmax + mu);
    let b = (l - alpha * nmin) / (l + alpha * nmin);
    a.max(b)
}

/// Theorem-1 contraction factor ρ(θ, τ, δ).
pub fn rate_bound(theta: f64, tau: f64, delta: f64) -> f64 {
    let om = (1.0 - theta).abs();
    om + theta * delta
        + (1.0 - tau).max(0.0).sqrt() * (theta + om * delta + delta)
}

/// Minimum τ for the Eq. (15) θ-domain to be non-empty.
pub fn tau_threshold(delta: f64) -> f64 {
    let r = (1.0 - delta) / (1.0 + delta);
    1.0 - r * r
}

/// The θ-domain of Eq. (15); `None` when empty.
pub fn theta_domain(tau: f64, delta: f64) -> Option<(f64, f64)> {
    if tau < tau_threshold(delta) - 1e-15 {
        return None;
    }
    let s = (1.0 - tau).max(0.0).sqrt();
    let lo = if s >= 1.0 {
        f64::INFINITY
    } else {
        2.0 * delta * s / ((1.0 - delta) * (1.0 - s))
    };
    let hi = 2.0 / ((1.0 + delta) * (1.0 + s));
    if lo < hi {
        Some((lo, hi))
    } else {
        None
    }
}

/// Which dual-update rule to run (the §3.2 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DualRule {
    /// Eq. (13): compress the update `y − z` (the C-ECL).
    CompressDiff,
    /// Eq. (11): compress `y` directly (shown not to work in §3.2).
    CompressY,
}

/// Exact C-ECL on the quadratic network. Returns `‖w − w*‖` per round
/// (stacked over nodes), starting at round 0 (initial error).
pub fn run_cecl(
    net: &QuadraticNetwork,
    graph: &Graph,
    alpha: f64,
    theta: f64,
    k_frac: f64,
    rounds: usize,
    seed: u64,
    rule: DualRule,
) -> Vec<f64> {
    let n = graph.n();
    assert_eq!(net.nodes.len(), n);
    let dim = net.dim;
    let comp = RandK::new(k_frac.clamp(1e-9, 1.0));

    // Per-node prox factorization: H_i + α|N_i| I.
    let factors: Vec<Cholesky> = (0..n)
        .map(|i| {
            let mut m = net.nodes[i].hess.clone();
            m.add_diag(alpha * graph.degree(i) as f64);
            Cholesky::new(&m).expect("prox matrix SPD")
        })
        .collect();

    // Dual state per directed pair (i, j): z[i][jj] with jj = neighbor
    // slot. Initialized to zero (as in the paper's experiments).
    let mut z: Vec<Vec<Vec<f64>>> = (0..n)
        .map(|i| vec![vec![0.0; dim]; graph.degree(i)])
        .collect();
    let mut w: Vec<Vec<f64>> = vec![vec![0.0; dim]; n];
    let mut errors = Vec::with_capacity(rounds + 1);

    let error = |w: &Vec<Vec<f64>>| -> f64 {
        let mut acc = 0.0;
        for wi in w {
            let d = linalg::sub(wi, &net.w_star);
            acc += linalg::dot(&d, &d);
        }
        acc.sqrt()
    };

    for round in 0..rounds {
        // Eq. (3): exact prox. rhs = btc_i + Σ_j a_ij z_{i|j}.
        for i in 0..n {
            let mut rhs = net.nodes[i].btc.clone();
            for (jj, &j) in graph.neighbors(i).iter().enumerate() {
                let a = graph.edge_sign(i, j) as f64;
                linalg::axpy(a, &z[i][jj], &mut rhs);
            }
            w[i] = factors[i].solve(&rhs);
        }
        if round == 0 {
            errors.push(error(&w));
        }

        // Eq. (4): y_{i|j} = z_{i|j} − 2α a_ij w_i, then the compressed
        // exchange + Eq. (13)/(11) update, sequentially simulated.
        // y values are computed from the PRE-update z of this round.
        let y: Vec<Vec<Vec<f64>>> = (0..n)
            .map(|i| {
                graph
                    .neighbors(i)
                    .iter()
                    .enumerate()
                    .map(|(jj, &j)| {
                        let a = graph.edge_sign(i, j) as f64;
                        let mut yv = z[i][jj].clone();
                        linalg::axpy(-2.0 * alpha * a, &w[i], &mut yv);
                        yv
                    })
                    .collect()
            })
            .collect();

        for i in 0..n {
            let neighbors: Vec<usize> = graph.neighbors(i).to_vec();
            for (jj, &j) in neighbors.iter().enumerate() {
                // ω_{i|j}: the mask for messages j -> i, shared-seed
                // derived identically at both endpoints.
                let e = graph.edge_index(i, j).unwrap() as u64;
                let dir = if i < j { 0 } else { 1 };
                let mut mrng = Pcg::derive(
                    seed,
                    &[streams::EDGE_MASK, e, round as u64, dir],
                );
                let mask = comp.sample_mask(dim, &mut mrng);
                // y_{j|i} as received from node j.
                let ii = graph.neighbors(j).iter().position(|&x| x == i).unwrap();
                let y_recv = &y[j][ii];
                match rule {
                    DualRule::CompressDiff => {
                        for &idx in &mask {
                            let idx = idx as usize;
                            z[i][jj][idx] +=
                                theta * (y_recv[idx] - z[i][jj][idx]);
                        }
                    }
                    DualRule::CompressY => {
                        // Eq. (11): z' = (1−θ)z + θ comp(y): unmasked
                        // coordinates of y are treated as zero.
                        for v in z[i][jj].iter_mut() {
                            *v *= 1.0 - theta;
                        }
                        for &idx in &mask {
                            let idx = idx as usize;
                            z[i][jj][idx] += theta * y_recv[idx];
                        }
                    }
                }
            }
        }

        // Record the error of the *next* w (computed at loop top), so do
        // one extra prox pass at the end instead; simpler: recompute here.
        let mut w_next: Vec<Vec<f64>> = vec![vec![0.0; dim]; n];
        for i in 0..n {
            let mut rhs = net.nodes[i].btc.clone();
            for (jj, &j) in graph.neighbors(i).iter().enumerate() {
                let a = graph.edge_sign(i, j) as f64;
                linalg::axpy(a, &z[i][jj], &mut rhs);
            }
            w_next[i] = factors[i].solve(&rhs);
        }
        errors.push(error(&w_next));
    }
    errors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::empirical_rate;

    fn net() -> (QuadraticNetwork, Graph) {
        let graph = Graph::ring(6);
        let net = QuadraticNetwork::random(6, 8, 12, 0.5, 0.5, 42);
        (net, graph)
    }

    #[test]
    fn spectral_constants_ordered() {
        let (net, _) = net();
        assert!(net.mu > 0.0);
        assert!(net.l_smooth >= net.mu);
    }

    #[test]
    fn grad_zero_at_node_optimum() {
        let (net, _) = net();
        // Solve node 0's own problem; gradient must vanish there.
        let chol = Cholesky::new(&net.nodes[0].hess).unwrap();
        let w0 = chol.solve(&net.nodes[0].btc);
        let g = net.nodes[0].grad(&w0);
        assert!(linalg::norm2(&g) < 1e-8);
    }

    #[test]
    fn global_optimum_stationary() {
        let (net, _) = net();
        // Σ_i ∇f_i(w*) = 0.
        let mut g_sum = vec![0.0; net.dim];
        for node in &net.nodes {
            linalg::axpy(1.0, &node.grad(&net.w_star), &mut g_sum);
        }
        assert!(linalg::norm2(&g_sum) < 1e-8, "{}", linalg::norm2(&g_sum));
    }

    #[test]
    fn delta_in_unit_interval() {
        let (net, graph) = net();
        for alpha in [0.01, 0.1, 1.0, 10.0] {
            let d = net.delta(alpha, &graph).expect("ring is non-empty");
            assert!((0.0..1.0).contains(&d), "alpha={alpha} delta={d}");
        }
    }

    #[test]
    fn best_alpha_beats_neighbors() {
        let (net, graph) = net();
        let a = net.best_alpha(&graph).expect("ring is non-empty");
        let d = net.delta(a, &graph).unwrap();
        assert!(d <= net.delta(a * 2.0, &graph).unwrap() + 1e-9);
        assert!(d <= net.delta(a / 2.0, &graph).unwrap() + 1e-9);
    }

    #[test]
    fn ecl_converges_linearly() {
        // τ = 1 (Corollary 1): exact ECL converges linearly.
        //
        // NOTE (soundness gap, see EXPERIMENTS.md §Theory): the measured
        // w-space rate can EXCEED the Theorem-1 bound |1−θ| + θδ.  The
        // paper's Lemma 2 claims f*(A·) is strongly convex, but A ∈
        // R^{dN x 2d|E|} has a nontrivial null space whenever |E| ≥ N/2
        // (e.g. any ring), so strong convexity fails along null(A) and
        // the contraction constant δ is not valid globally.  We assert
        // the qualitative claim (linear convergence) and *report* the
        // measured-vs-bound gap in `repro theory`.
        let (net, graph) = net();
        let alpha = net.best_alpha(&graph).expect("ring is non-empty");
        let errors = run_cecl(&net, &graph, alpha, 1.0, 1.0, 120, 7,
                              DualRule::CompressDiff);
        let rate = empirical_rate(&errors[20..]);
        assert!(errors.last().unwrap() < &(errors[0] * 1e-4),
                "final {:?}", errors.last());
        assert!(rate < 0.97, "rate {rate} not linear");
        // Consecutive-ratio stability => genuinely linear (geometric).
        let tail = &errors[40..];
        let ratios: Vec<f64> =
            tail.windows(2).map(|w| w[1] / w[0]).collect();
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(
            ratios.iter().all(|r| (r - mean).abs() < 0.25),
            "ratios not stable: {ratios:?}"
        );
    }

    #[test]
    fn cecl_converges_within_theory_domain() {
        let (net, graph) = net();
        let alpha = net.best_alpha(&graph).expect("ring is non-empty");
        let delta = net.delta(alpha, &graph).unwrap();
        // Choose τ safely above the threshold; θ = 1 (Corollary 2).
        let tau = (tau_threshold(delta) + 1.0) / 2.0;
        let errors = run_cecl(&net, &graph, alpha, 1.0, tau, 250, 9,
                              DualRule::CompressDiff);
        assert!(rate_bound(1.0, tau, delta) < 1.0);
        let rate = empirical_rate(&errors[20..]);
        assert!(rate < 1.0, "not contracting: {rate}");
        assert!(
            errors.last().unwrap() < &(errors[0] * 1e-2),
            "final {:?} vs initial {}",
            errors.last(),
            errors[0]
        );
    }

    #[test]
    fn more_compression_is_slower() {
        // Qualitative Theorem-1 shape: the measured rate degrades as τ
        // shrinks (more compression).
        let (net, graph) = net();
        let alpha = net.best_alpha(&graph).expect("ring is non-empty");
        let r = |tau: f64| {
            let e = run_cecl(&net, &graph, alpha, 1.0, tau, 150, 21,
                             DualRule::CompressDiff);
            empirical_rate(&e[30..])
        };
        let r_full = r(1.0);
        let r_mid = r(0.7);
        let r_low = r(0.4);
        assert!(r_full <= r_mid + 0.02, "{r_full} vs {r_mid}");
        assert!(r_mid <= r_low + 0.02, "{r_mid} vs {r_low}");
    }

    #[test]
    fn theta_one_is_optimal_corollary2() {
        // Corollary 2 is a statement about the BOUND: ρ(θ) is minimized
        // at θ = 1 — that is pure arithmetic of the formula and must
        // hold exactly.
        let (net, graph) = net();
        let alpha = net.best_alpha(&graph).expect("ring is non-empty");
        let delta = net.delta(alpha, &graph).unwrap();
        let tau = (tau_threshold(delta) + 1.0) / 2.0;
        for theta in [0.3, 0.6, 0.8, 1.2, 1.4] {
            assert!(
                rate_bound(1.0, tau, delta) <= rate_bound(theta, tau, delta),
                "theta={theta}"
            );
        }
        // Empirically both θ=1 and θ=0.7 converge (ordering is noisy on
        // a single instance — the driver reports the sweep).
        let e1 = run_cecl(&net, &graph, alpha, 1.0, tau, 120, 11,
                          DualRule::CompressDiff);
        let e07 = run_cecl(&net, &graph, alpha, 0.7, tau, 120, 11,
                           DualRule::CompressDiff);
        assert!(e1.last().unwrap() < &(e1[0] * 1e-2));
        assert!(e07.last().unwrap() < &(e07[0] * 1e-2));
    }

    #[test]
    fn theta_domain_shrinks_with_tau() {
        let delta = 0.5;
        let full = theta_domain(1.0, delta).unwrap();
        assert!(full.0 == 0.0 && (full.1 - 2.0 / 1.5).abs() < 1e-12);
        let tau = (tau_threshold(delta) + 1.0) / 2.0;
        let tight = theta_domain(tau, delta).unwrap();
        assert!(tight.0 > full.0);
        assert!(tight.1 < full.1);
        assert!(tight.0 < 1.0 && 1.0 < tight.1, "domain contains 1");
        // Below the threshold the domain is empty.
        assert!(theta_domain(tau_threshold(delta) * 0.9, delta).is_none());
    }

    #[test]
    fn naive_y_compression_worse_ablation() {
        // §3.2: compressing y directly does not work — with the same
        // budget the Eq. (13) rule must end with (much) smaller error.
        let (net, graph) = net();
        let alpha = net.best_alpha(&graph).expect("ring is non-empty");
        let e_diff = run_cecl(&net, &graph, alpha, 1.0, 0.5, 150, 13,
                              DualRule::CompressDiff);
        let e_y = run_cecl(&net, &graph, alpha, 1.0, 0.5, 150, 13,
                           DualRule::CompressY);
        assert!(
            e_diff.last().unwrap() * 10.0 < *e_y.last().unwrap(),
            "diff {:?} vs y {:?}",
            e_diff.last(),
            e_y.last()
        );
    }
}
