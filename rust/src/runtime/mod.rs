//! PJRT runtime: load the AOT-compiled HLO text artifacts and execute
//! them from the rust hot path (the L3 <-> L2 bridge).
//!
//! Wraps the published `xla` crate (0.1.6):
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `client.compile` -> `execute`.  Executables are compiled once at
//! startup and shared across node threads.
//!
//! ## Feature gate
//!
//! The `xla` crate needs the XLA extension shared library at build
//! time, so the whole PJRT path sits behind the off-by-default `pjrt`
//! cargo feature.  Without it this module still compiles: the types
//! keep their signatures and [`Engine::cpu`] returns a descriptive
//! error, so artifact-dependent tests self-skip and everything else
//! (both execution engines, the [`native`] twin of the dual update, the
//! artifact-free simulator backend) runs normally.
//!
//! ## Thread safety
//!
//! The `xla` crate's handles are raw-pointer newtypes without `Send`/
//! `Sync` impls.  The underlying PJRT CPU client (`TfrtCpuClient`) *is*
//! thread-safe: compilation and execution take `const` handles and the
//! runtime internally locks/schedules (this is the same property the
//! Python jax runtime relies on when dispatching from multiple threads).
//! [`Executable`] therefore carries a documented `unsafe impl Send +
//! Sync`; every node thread executes through a shared `Arc<ModelRuntime>`.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(not(feature = "pjrt"))]
use anyhow::anyhow;

use crate::model::DatasetManifest;

/// Typed input to an executable.
pub enum In<'a> {
    /// f32 tensor with explicit dims (row-major).
    F32(&'a [f32], &'a [i64]),
    /// i32 tensor with explicit dims.
    I32(&'a [i32], &'a [i64]),
    /// f32 scalar.
    ScalarF32(f32),
}

#[cfg(feature = "pjrt")]
impl<'a> In<'a> {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            In::F32(data, dims) => {
                let expect: i64 = dims.iter().product();
                if expect as usize != data.len() {
                    bail!("In::F32: {} elems vs dims {:?}", data.len(), dims);
                }
                Ok(xla::Literal::vec1(data).reshape(dims)?)
            }
            In::I32(data, dims) => {
                let expect: i64 = dims.iter().product();
                if expect as usize != data.len() {
                    bail!("In::I32: {} elems vs dims {:?}", data.len(), dims);
                }
                Ok(xla::Literal::vec1(data).reshape(dims)?)
            }
            In::ScalarF32(v) => Ok(xla::Literal::scalar(*v)),
        }
    }
}

/// A compiled HLO module, executable from any thread (see module docs).
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

// SAFETY: `PjRtLoadedExecutable` owns an opaque handle to a PJRT CPU
// executable; the PJRT C API guarantees `Execute` may be called from
// any thread, and nothing else on the rust side touches the handle, so
// moving the wrapper across threads is sound.
#[cfg(feature = "pjrt")]
unsafe impl Send for Executable {}
// SAFETY: `&Executable` only ever reaches `execute`, which the PJRT
// runtime internally synchronizes; the wrapped pointer is never
// mutated through a shared reference on the rust side.
#[cfg(feature = "pjrt")]
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with the given inputs; returns every tuple output as a
    /// flat f32 vector (the artifacts are lowered with
    /// `return_tuple=True`).
    #[cfg(feature = "pjrt")]
    pub fn run(&self, inputs: &[In<'_>]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("building inputs for {}", self.name))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let parts = tuple
            .decompose_tuple()
            .with_context(|| format!("decomposing result of {}", self.name))?;
        parts
            .into_iter()
            .map(|lit| Ok(lit.to_vec::<f32>()?))
            .collect()
    }

    /// Stub: unreachable in practice because [`Engine::cpu`] already
    /// fails without the feature, but keeps call sites compiling.
    #[cfg(not(feature = "pjrt"))]
    pub fn run(&self, _inputs: &[In<'_>]) -> Result<Vec<Vec<f32>>> {
        bail!("{}: built without the `pjrt` feature", self.name)
    }
}

/// PJRT client plus artifact loader.
pub struct Engine {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
}

// SAFETY: `PjRtClient` is an opaque handle to the PJRT CPU client,
// which the C API documents as usable from any thread; the handle is
// only consumed by compile/load calls, so ownership may migrate.
#[cfg(feature = "pjrt")]
unsafe impl Send for Engine {}
// SAFETY: shared references only reach the client's compile/load entry
// points, which PJRT synchronizes internally — same argument as
// `Executable` above.
#[cfg(feature = "pjrt")]
unsafe impl Sync for Engine {}

impl Engine {
    /// Create the PJRT CPU client.
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    /// Without the `pjrt` feature there is no client to create; tests
    /// that need one self-skip on the artifacts check before reaching
    /// this.
    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> Result<Engine> {
        Err(anyhow!(
            "PJRT runtime unavailable: rebuild with `--features pjrt` \
             (requires the xla crate and its XLA extension library)"
        ))
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "unavailable (built without pjrt)".to_string()
        }
    }

    /// Load + compile one HLO text artifact.
    #[cfg(feature = "pjrt")]
    pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "<hlo>".to_string()),
        })
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        bail!(
            "cannot load {:?}: built without the `pjrt` feature",
            path.as_ref()
        )
    }
}

/// All compiled entry points for one dataset-scale model, shared across
/// node threads via `Arc`.
pub struct ModelRuntime {
    pub ds: DatasetManifest,
    train: Executable,
    eval: Executable,
    dual: Executable,
}

impl ModelRuntime {
    pub fn load(engine: &Engine, ds: &DatasetManifest) -> Result<Arc<ModelRuntime>> {
        Ok(Arc::new(ModelRuntime {
            ds: ds.clone(),
            train: engine.load_hlo(&ds.train_step)?,
            eval: engine.load_hlo(&ds.eval_step)?,
            dual: engine.load_hlo(&ds.dual_update)?,
        }))
    }

    /// One Eq. (6) local update. `alpha_deg = α·|N_i|`; with
    /// `alpha_deg = 0` and `zsum = 0` this is a plain SGD step.
    /// Returns `(w_next, loss)`.
    pub fn train_step(
        &self,
        w: &[f32],
        zsum: &[f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        alpha_deg: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let d = self.ds.d_pad as i64;
        let (h, wd, c) = self.ds.input;
        let b = self.ds.batch as i64;
        let dims = [b, h as i64, wd as i64, c as i64];
        let mut out = self.train.run(&[
            In::F32(w, &[d]),
            In::F32(zsum, &[d]),
            In::F32(x, &dims),
            In::I32(y, &[b]),
            In::ScalarF32(eta),
            In::ScalarF32(alpha_deg),
        ])?;
        if out.len() != 2 {
            bail!("train_step: expected 2 outputs, got {}", out.len());
        }
        let loss = out.pop().unwrap();
        let w_next = out.pop().unwrap();
        Ok((w_next, loss[0]))
    }

    /// One eval batch -> (correct_count, loss_sum).
    pub fn eval_batch(&self, w: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let d = self.ds.d_pad as i64;
        let (h, wd, c) = self.ds.input;
        let b = self.ds.eval_batch as i64;
        let dims = [b, h as i64, wd as i64, c as i64];
        let out = self.eval.run(&[
            In::F32(w, &[d]),
            In::F32(x, &dims),
            In::I32(y, &[b]),
        ])?;
        if out.len() != 2 {
            bail!("eval: expected 2 outputs, got {}", out.len());
        }
        Ok((out[0][0], out[1][0]))
    }

    /// Full-test-set evaluation -> (accuracy, mean_loss). The test set
    /// size must be a multiple of the AOT eval batch.
    pub fn evaluate(&self, w: &[f32], test: &crate::data::Dataset) -> Result<(f64, f64)> {
        let be = self.ds.eval_batch;
        if test.n % be != 0 {
            bail!("test size {} not a multiple of eval batch {be}", test.n);
        }
        let slen = test.sample_len;
        let mut correct = 0.0f64;
        let mut loss = 0.0f64;
        for chunk in 0..test.n / be {
            let xs = &test.x[chunk * be * slen..(chunk + 1) * be * slen];
            let ys = &test.y[chunk * be..(chunk + 1) * be];
            let (c, l) = self.eval_batch(w, xs, ys)?;
            correct += c as f64;
            loss += l as f64;
        }
        Ok((correct / test.n as f64, loss / test.n as f64))
    }

    /// The fused L1 dual update (Alg. 1 lines 4 & 9) through PJRT:
    /// returns `(z_new, y_send_comp)`.
    #[allow(clippy::too_many_arguments)]
    pub fn dual_update(
        &self,
        z: &[f32],
        w: &[f32],
        ycomp_in: &[f32],
        m_in: &[f32],
        m_out: &[f32],
        theta: f32,
        two_alpha_a: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = self.ds.d_pad as i64;
        let mut out = self.dual.run(&[
            In::F32(z, &[d]),
            In::F32(w, &[d]),
            In::F32(ycomp_in, &[d]),
            In::F32(m_in, &[d]),
            In::F32(m_out, &[d]),
            In::ScalarF32(theta),
            In::ScalarF32(two_alpha_a),
        ])?;
        if out.len() != 2 {
            bail!("dual_update: expected 2 outputs, got {}", out.len());
        }
        let ysend = out.pop().unwrap();
        let znew = out.pop().unwrap();
        Ok((znew, ysend))
    }
}

/// Native (pure-rust) twin of the fused dual update, used on the default
/// hot path (ablation `dual-path` in EXPERIMENTS.md §Perf compares the
/// two).  Must stay semantically identical to the L1 kernel — the
/// integration tests assert elementwise agreement against the PJRT path.
pub mod native {
    /// `z' = z + θ(ycomp − m_in∘z)`, `y_send = m_out∘(z − taa·w)`,
    /// writing into preallocated outputs.
    #[allow(clippy::too_many_arguments)]
    pub fn dual_update_into(
        z: &[f32],
        w: &[f32],
        ycomp_in: &[f32],
        m_in: &[f32],
        m_out: &[f32],
        theta: f32,
        two_alpha_a: f32,
        z_new: &mut [f32],
        y_send: &mut [f32],
    ) {
        let d = z.len();
        assert!(
            w.len() == d
                && ycomp_in.len() == d
                && m_in.len() == d
                && m_out.len() == d
                && z_new.len() == d
                && y_send.len() == d
        );
        for i in 0..d {
            let zi = z[i];
            y_send[i] = m_out[i] * (zi - two_alpha_a * w[i]);
            z_new[i] = zi + theta * (ycomp_in[i] - m_in[i] * zi);
        }
    }

    /// Sparse-aware variant: the receive side applies
    /// `z' = z + θ·(comp(y_recv) − comp(z))` directly from the COO
    /// message and the shared mask indices — no dense mask vectors at
    /// all.  `y_send` values are gathered for the outbound mask.
    pub fn dual_update_sparse(
        z: &mut [f32],
        w: &[f32],
        ycomp_in: &crate::compress::CooVec,
        mask_out: &[u32],
        theta: f32,
        two_alpha_a: f32,
        y_send_vals: &mut Vec<f32>,
    ) {
        // Outbound gather first (y must use the pre-update z).
        y_send_vals.clear();
        y_send_vals.reserve(mask_out.len());
        for &i in mask_out {
            let i = i as usize;
            y_send_vals.push(z[i] - two_alpha_a * w[i]);
        }
        // In-place receive update only touches masked coordinates.
        for (&i, &yv) in ycomp_in.idx.iter().zip(&ycomp_in.val) {
            let i = i as usize;
            z[i] += theta * (yv - z[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CooVec;
    use crate::util::rng::Pcg;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn native_dense_matches_formula() {
        let d = 257;
        let z = randn(d, 1);
        let w = randn(d, 2);
        let y = randn(d, 3);
        let mut m_in = vec![0.0f32; d];
        let mut m_out = vec![0.0f32; d];
        for i in (0..d).step_by(3) {
            m_in[i] = 1.0;
        }
        for i in (0..d).step_by(4) {
            m_out[i] = 1.0;
        }
        let ycomp: Vec<f32> = y.iter().zip(&m_in).map(|(a, b)| a * b).collect();
        let mut zn = vec![0.0f32; d];
        let mut ys = vec![0.0f32; d];
        native::dual_update_into(&z, &w, &ycomp, &m_in, &m_out, 0.7, 0.3,
                                 &mut zn, &mut ys);
        for i in 0..d {
            let want_z = z[i] + 0.7 * (ycomp[i] - m_in[i] * z[i]);
            let want_y = m_out[i] * (z[i] - 0.3 * w[i]);
            assert!((zn[i] - want_z).abs() < 1e-6);
            assert!((ys[i] - want_y).abs() < 1e-6);
        }
    }

    #[test]
    fn native_sparse_matches_dense() {
        let d = 300;
        let z0 = randn(d, 4);
        let w = randn(d, 5);
        let y_recv = randn(d, 6);
        let mask_in: Vec<u32> = (0..d as u32).filter(|i| i % 3 == 0).collect();
        let mask_out: Vec<u32> = (0..d as u32).filter(|i| i % 5 == 0).collect();
        let mut m_in_dense = vec![0.0f32; d];
        let mut m_out_dense = vec![0.0f32; d];
        for &i in &mask_in {
            m_in_dense[i as usize] = 1.0;
        }
        for &i in &mask_out {
            m_out_dense[i as usize] = 1.0;
        }
        let ycomp_dense: Vec<f32> =
            y_recv.iter().zip(&m_in_dense).map(|(a, b)| a * b).collect();

        // Dense reference.
        let mut zn = vec![0.0f32; d];
        let mut ys = vec![0.0f32; d];
        native::dual_update_into(&z0, &w, &ycomp_dense, &m_in_dense,
                                 &m_out_dense, 0.9, 1.1, &mut zn, &mut ys);

        // Sparse path.
        let coo = CooVec::gather(&y_recv, &mask_in);
        let mut z_sparse = z0.clone();
        let mut yvals = Vec::new();
        native::dual_update_sparse(&mut z_sparse, &w, &coo, &mask_out, 0.9,
                                   1.1, &mut yvals);
        for i in 0..d {
            assert!((z_sparse[i] - zn[i]).abs() < 1e-6, "z at {i}");
        }
        for (k, &i) in mask_out.iter().enumerate() {
            assert!((yvals[k] - ys[i as usize]).abs() < 1e-6, "y at {i}");
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn engine_without_pjrt_reports_clearly() {
        let err = Engine::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
