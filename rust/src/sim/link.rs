//! Pluggable link models for the virtual-time engine: how long a
//! message of `b` bytes occupies a directed edge, how long it then
//! propagates, and how many transmission attempts it burns.
//!
//! Four models cover the evaluation regimes of the compression
//! literature (Koloskova et al. 2019; Vogels et al. 2020):
//!
//! * [`IdealLink`] — zero latency, lossless: reproduces the threaded
//!   bus exactly (byte-accounting equivalence is pinned by tests).
//! * [`ConstantLatency`] — fixed propagation delay per message.
//! * [`BandwidthLink`] — latency + serialization delay `bytes / rate`,
//!   which is what makes compression a *time* win, not just a byte win.
//! * [`LossyLink`] — i.i.d. packet drop with stop-and-wait retransmit:
//!   each failed attempt burns a full serialization+timeout slot and is
//!   accounted as retransmitted bytes on the sender's meter.
//!
//! A transmission is split into **occupancy** (how long the directed
//! channel is busy serializing, retries included) and **latency**
//! (propagation after the last serialization).  The engine queues
//! occupancy per directed edge — two messages queued on the same edge
//! serialize back-to-back, never in parallel — so bandwidth-bound
//! traffic costs what a serial link actually costs.
//!
//! All randomness comes from the engine's deterministic link RNG, so a
//! run is bit-reproducible from its seed.

use crate::util::rng::Pcg;

/// Failed attempts are capped so a pathological drop probability cannot
/// stall virtual time forever (2⁻⁶⁴-grade improbable at sane `drop_p`).
const MAX_ATTEMPTS: u32 = 64;

/// Outcome of transmitting one message over a directed edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transmission {
    /// Virtual nanoseconds the directed channel is busy (serialization
    /// of every attempt plus retransmit timeouts).  The engine starts
    /// the next message on this edge only after this one's occupancy.
    pub occupancy_ns: u64,
    /// Propagation delay between the final serialization and delivery.
    pub latency_ns: u64,
    /// Total transmission attempts (1 = no drops).
    pub attempts: u32,
}

impl Transmission {
    /// Send-to-delivery time when the channel is free at send time.
    pub fn delay_ns(&self) -> u64 {
        self.occupancy_ns.saturating_add(self.latency_ns)
    }

    /// Extra wire bytes burned beyond the first copy of a `payload`-byte
    /// message.
    pub fn retransmit_bytes(&self, payload: usize) -> u64 {
        (self.attempts as u64 - 1) * payload as u64
    }
}

/// A link model maps (message size, randomness) to a transmission
/// outcome.  Implementations must be deterministic given the RNG state.
pub trait LinkModel: Send + Sync {
    fn name(&self) -> String;

    fn transmit(&self, bytes: usize, rng: &mut Pcg) -> Transmission;
}

/// Zero-latency, lossless: the threaded bus's semantics in virtual time.
#[derive(Debug, Clone, Copy)]
pub struct IdealLink;

impl LinkModel for IdealLink {
    fn name(&self) -> String {
        "ideal".to_string()
    }

    fn transmit(&self, _bytes: usize, _rng: &mut Pcg) -> Transmission {
        Transmission {
            occupancy_ns: 0,
            latency_ns: 0,
            attempts: 1,
        }
    }
}

/// Fixed propagation delay, lossless, infinite bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct ConstantLatency {
    pub latency_ns: u64,
}

impl LinkModel for ConstantLatency {
    fn name(&self) -> String {
        format!("constant({}us)", self.latency_ns / 1_000)
    }

    fn transmit(&self, _bytes: usize, _rng: &mut Pcg) -> Transmission {
        Transmission {
            occupancy_ns: 0,
            latency_ns: self.latency_ns,
            attempts: 1,
        }
    }
}

fn serialization_ns(bytes: usize, bytes_per_sec: f64) -> u64 {
    debug_assert!(bytes_per_sec > 0.0, "bandwidth must be positive");
    (bytes as f64 * 1e9 / bytes_per_sec) as u64
}

/// Latency plus bandwidth-proportional serialization delay.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthLink {
    pub latency_ns: u64,
    pub bytes_per_sec: f64,
}

impl LinkModel for BandwidthLink {
    fn name(&self) -> String {
        format!(
            "bw({}us,{:.0}Mbit/s)",
            self.latency_ns / 1_000,
            self.bytes_per_sec * 8.0 / 1e6
        )
    }

    fn transmit(&self, bytes: usize, _rng: &mut Pcg) -> Transmission {
        Transmission {
            occupancy_ns: serialization_ns(bytes, self.bytes_per_sec),
            latency_ns: self.latency_ns,
            attempts: 1,
        }
    }
}

/// Bandwidth link with i.i.d. per-message drop probability and
/// stop-and-wait retransmission.
#[derive(Debug, Clone, Copy)]
pub struct LossyLink {
    pub latency_ns: u64,
    pub bytes_per_sec: f64,
    /// Probability that one transmission attempt is lost.
    pub drop_p: f64,
}

impl LinkModel for LossyLink {
    fn name(&self) -> String {
        format!(
            "lossy({}us,{:.0}Mbit/s,p={})",
            self.latency_ns / 1_000,
            self.bytes_per_sec * 8.0 / 1e6,
            self.drop_p
        )
    }

    fn transmit(&self, bytes: usize, rng: &mut Pcg) -> Transmission {
        debug_assert!(
            (0.0..1.0).contains(&self.drop_p),
            "drop_p in [0, 1) — validated at LinkSpec construction"
        );
        let mut attempts = 1u32;
        while attempts < MAX_ATTEMPTS && rng.bernoulli(self.drop_p) {
            attempts += 1;
        }
        let ser = serialization_ns(bytes, self.bytes_per_sec);
        // Every failed attempt holds the channel for a serialization
        // plus one latency's worth of timeout before the retry.
        Transmission {
            occupancy_ns: (ser + self.latency_ns) * (attempts as u64 - 1) + ser,
            latency_ns: self.latency_ns,
            attempts,
        }
    }
}

/// Declarative, `Clone`/`Debug`-able link selection that lives inside
/// `ExperimentSpec` (trait objects would poison the spec's derives).
#[derive(Debug, Clone, PartialEq)]
pub enum LinkSpec {
    Ideal,
    Constant {
        latency_us: u64,
    },
    Bandwidth {
        latency_us: u64,
        mbit_per_sec: f64,
    },
    Lossy {
        latency_us: u64,
        mbit_per_sec: f64,
        drop_p: f64,
    },
}

/// Compact `LinkSpec` grammar shared by every parse error.
const LINK_GRAMMAR: &str = "ideal | constant:<latency_us> | \
                            bandwidth:<latency_us>:<mbit_per_sec> | \
                            lossy:<latency_us>:<mbit_per_sec>:<drop_p>";

impl LinkSpec {
    /// Parse the compact one-token grammar used by `--edge-link`
    /// (`ideal`, `constant:500`, `bandwidth:500:100`,
    /// `lossy:500:100:0.05`).  Errors name the offending token and
    /// restate the grammar.
    pub fn parse(s: &str) -> anyhow::Result<LinkSpec> {
        let s = s.trim();
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or("");
        let args: Vec<&str> = parts.collect();
        let int = |a: &str, what: &str| -> anyhow::Result<u64> {
            a.parse::<u64>().map_err(|_| {
                anyhow::anyhow!(
                    "link spec `{s}`: `{a}` is not a {what} \
                     (grammar: {LINK_GRAMMAR})"
                )
            })
        };
        let num = |a: &str, what: &str| -> anyhow::Result<f64> {
            a.parse::<f64>().map_err(|_| {
                anyhow::anyhow!(
                    "link spec `{s}`: `{a}` is not a {what} \
                     (grammar: {LINK_GRAMMAR})"
                )
            })
        };
        let spec = match (head, args.as_slice()) {
            ("ideal", []) => LinkSpec::Ideal,
            ("constant", [lat]) => LinkSpec::Constant {
                latency_us: int(lat, "latency in microseconds")?,
            },
            ("bandwidth" | "bw", [lat, mbit]) => LinkSpec::Bandwidth {
                latency_us: int(lat, "latency in microseconds")?,
                mbit_per_sec: num(mbit, "bandwidth in Mbit/s")?,
            },
            ("lossy", [lat, mbit, drop]) => LinkSpec::Lossy {
                latency_us: int(lat, "latency in microseconds")?,
                mbit_per_sec: num(mbit, "bandwidth in Mbit/s")?,
                drop_p: num(drop, "drop probability")?,
            },
            _ => anyhow::bail!(
                "unknown link spec `{s}` (grammar: {LINK_GRAMMAR})"
            ),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Validate the parameters (positive rates, `drop_p ∈ [0, 1)`).
    pub fn validate(&self) -> anyhow::Result<()> {
        match *self {
            LinkSpec::Ideal | LinkSpec::Constant { .. } => Ok(()),
            LinkSpec::Bandwidth { mbit_per_sec, .. } => {
                anyhow::ensure!(
                    mbit_per_sec > 0.0 && mbit_per_sec.is_finite(),
                    "link bandwidth must be positive, got {mbit_per_sec}"
                );
                Ok(())
            }
            LinkSpec::Lossy { mbit_per_sec, drop_p, .. } => {
                anyhow::ensure!(
                    mbit_per_sec > 0.0 && mbit_per_sec.is_finite(),
                    "link bandwidth must be positive, got {mbit_per_sec}"
                );
                anyhow::ensure!(
                    (0.0..1.0).contains(&drop_p),
                    "drop probability must be in [0, 1), got {drop_p}"
                );
                Ok(())
            }
        }
    }

    pub fn build(&self) -> Box<dyn LinkModel> {
        match *self {
            LinkSpec::Ideal => Box::new(IdealLink),
            LinkSpec::Constant { latency_us } => Box::new(ConstantLatency {
                latency_ns: latency_us * 1_000,
            }),
            LinkSpec::Bandwidth { latency_us, mbit_per_sec } => {
                Box::new(BandwidthLink {
                    latency_ns: latency_us * 1_000,
                    bytes_per_sec: mbit_per_sec * 1e6 / 8.0,
                })
            }
            LinkSpec::Lossy { latency_us, mbit_per_sec, drop_p } => {
                Box::new(LossyLink {
                    latency_ns: latency_us * 1_000,
                    bytes_per_sec: mbit_per_sec * 1e6 / 8.0,
                    drop_p,
                })
            }
        }
    }

    pub fn name(&self) -> String {
        self.build().name()
    }

    /// Lower bound on the delivery delay of any message over this
    /// link, in virtual nanoseconds.  Every model computes `arrival >=
    /// departure + latency` with `departure >= send time` (occupancy,
    /// outages, busy couriers and FIFO ordering only push `departure`
    /// later), so the propagation latency bounds the delay from below.
    ///
    /// This is the conservative-PDES lookahead: a partition that has
    /// processed every event up to virtual time `T` cannot receive a
    /// new cross-partition message before `T + min_latency_ns()`.
    pub fn min_latency_ns(&self) -> u64 {
        match *self {
            LinkSpec::Ideal => 0,
            LinkSpec::Constant { latency_us }
            | LinkSpec::Bandwidth { latency_us, .. }
            | LinkSpec::Lossy { latency_us, .. } => latency_us * 1_000,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_free() {
        let mut rng = Pcg::new(1);
        let t = IdealLink.transmit(1_000_000, &mut rng);
        assert_eq!(t.delay_ns(), 0);
        assert_eq!(t.attempts, 1);
        assert_eq!(t.retransmit_bytes(1_000_000), 0);
    }

    #[test]
    fn bandwidth_serialization_math() {
        // 1 MB at 8 Mbit/s = 1 MB at 1 MB/s = 1 second of occupancy
        // plus the propagation latency.
        let link = BandwidthLink { latency_ns: 5_000, bytes_per_sec: 1e6 };
        let mut rng = Pcg::new(2);
        let t = link.transmit(1_000_000, &mut rng);
        assert_eq!(t.occupancy_ns, 1_000_000_000);
        assert_eq!(t.latency_ns, 5_000);
        assert_eq!(t.delay_ns(), 5_000 + 1_000_000_000);
        // Serialization scales linearly with size.
        let t2 = link.transmit(500_000, &mut rng);
        assert_eq!(t2.occupancy_ns, 500_000_000);
    }

    #[test]
    fn lossy_retransmits_and_is_deterministic() {
        let link = LossyLink {
            latency_ns: 1_000,
            bytes_per_sec: 1e9,
            drop_p: 0.5,
        };
        let total_attempts = |seed: u64| -> u32 {
            let mut rng = Pcg::new(seed);
            (0..200).map(|_| link.transmit(100, &mut rng).attempts).sum()
        };
        // Deterministic given the seed.
        assert_eq!(total_attempts(7), total_attempts(7));
        // With p=0.5 over 200 messages, mean attempts ≈ 2: retransmits
        // must actually happen.
        assert!(total_attempts(7) > 250);
        // 1000 B at 1 GB/s serializes in 1000 ns; every retry burns a
        // serialization + timeout slot, so total delay is
        // attempts x (ser + latency) = attempts x 2000 ns.
        let mut rng = Pcg::new(9);
        for _ in 0..50 {
            let t = link.transmit(1_000, &mut rng);
            assert_eq!(t.delay_ns(), 2_000 * t.attempts as u64);
            assert_eq!(t.latency_ns, 1_000);
        }
    }

    #[test]
    fn lossless_models_never_retransmit() {
        let mut rng = Pcg::new(3);
        for _ in 0..100 {
            assert_eq!(IdealLink.transmit(64, &mut rng).attempts, 1);
            assert_eq!(
                ConstantLatency { latency_ns: 10 }.transmit(64, &mut rng).attempts,
                1
            );
        }
    }

    #[test]
    fn spec_builds_matching_models() {
        assert_eq!(LinkSpec::Ideal.name(), "ideal");
        let spec = LinkSpec::Lossy {
            latency_us: 100,
            mbit_per_sec: 80.0,
            drop_p: 0.1,
        };
        assert!(spec.validate().is_ok());
        let model = spec.build();
        let mut rng = Pcg::new(4);
        // 80 Mbit/s = 10 MB/s: 10_000 bytes serialize in 1 ms.
        let t = model.transmit(10_000, &mut rng);
        assert!(t.delay_ns() >= 100_000 + 1_000_000);
    }

    #[test]
    fn parse_compact_grammar() {
        assert_eq!(LinkSpec::parse("ideal").unwrap(), LinkSpec::Ideal);
        assert_eq!(
            LinkSpec::parse("constant:500").unwrap(),
            LinkSpec::Constant { latency_us: 500 }
        );
        assert_eq!(
            LinkSpec::parse("bw:500:100").unwrap(),
            LinkSpec::Bandwidth { latency_us: 500, mbit_per_sec: 100.0 }
        );
        assert_eq!(
            LinkSpec::parse("lossy:200:50:0.1").unwrap(),
            LinkSpec::Lossy {
                latency_us: 200,
                mbit_per_sec: 50.0,
                drop_p: 0.1
            }
        );
        // Errors name the offending token and restate the grammar.
        let err = LinkSpec::parse("constant:fast").unwrap_err();
        assert!(err.to_string().contains("`fast`"), "{err}");
        assert!(err.to_string().contains("grammar"), "{err}");
        let err = LinkSpec::parse("warp:1").unwrap_err();
        assert!(err.to_string().contains("`warp:1`"), "{err}");
        // Out-of-range parameters still go through validate().
        assert!(LinkSpec::parse("lossy:200:50:1.5").is_err());
        assert!(LinkSpec::parse("bandwidth:200:0").is_err());
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        assert!(LinkSpec::Lossy {
            latency_us: 0,
            mbit_per_sec: 10.0,
            drop_p: 1.0
        }
        .validate()
        .is_err());
        assert!(LinkSpec::Lossy {
            latency_us: 0,
            mbit_per_sec: 10.0,
            drop_p: -0.1
        }
        .validate()
        .is_err());
        assert!(LinkSpec::Bandwidth {
            latency_us: 0,
            mbit_per_sec: 0.0
        }
        .validate()
        .is_err());
        assert!(LinkSpec::Ideal.validate().is_ok());
    }
}
