//! Event-driven virtual-time network simulator — the crate's second
//! execution engine.
//!
//! The threaded coordinator (one OS thread per node, blocking channels)
//! models a perfect network: zero latency, lossless, and it cannot
//! scale past a few dozen nodes or report anything but byte counts.
//! This engine replaces threads with poll-driven state machines
//! ([`NodeStateMachine`](crate::algorithms::NodeStateMachine)) scheduled
//! off a binary-heap event queue keyed by **virtual nanoseconds**:
//!
//! * one thread simulates 512+ nodes (the scale lever),
//! * no thread spawn/park overhead in benches (the speed lever),
//! * messages travel through pluggable [`LinkModel`]s — constant
//!   latency, bandwidth-proportional serialization, i.i.d. drop with
//!   retransmit byte accounting, heterogeneous per-edge overrides
//!   (`SimConfig::edge_links`) — plus per-node straggler slowdowns and
//!   a scheduled [`ChurnSchedule`](crate::graph::ChurnSchedule):
//!   state-preserving edge *outages* (traffic held until the window
//!   ends) and state-tearing *churn* (edge removal / node join-leave),
//!   so *time-to-accuracy* under imperfect networks becomes measurable
//!   (the scenario lever),
//! * topology churn is a **first-class event**: at every transition
//!   boundary the engine updates its epoch-stamped
//!   [`TopologyView`](crate::graph::TopologyView), notifies the
//!   affected machines (which retire / warm-start per-edge state), and
//!   re-polls their gates.  A removed edge drains its in-flight frames
//!   as typed churn drops (metered, never a panic); a revived edge is a
//!   fresh incarnation activating at `1 + max(endpoint rounds)` so both
//!   endpoints open it at the same round number.  Staleness bounds are
//!   evaluated over currently-live edges only (the churn lever),
//! * rounds follow a [`RoundPolicy`]: the classic bulk-synchronous
//!   barrier (`Sync`, trajectory-identical to the threaded bus), or
//!   gossip-style `Async { max_staleness }` where every message is
//!   delivered the moment it arrives (per-edge FIFO, stamped with the
//!   sender's round) and a node steps once each edge is at most
//!   `max_staleness` rounds stale — a straggler or one slow edge then
//!   delays only its own edges (the async lever).
//!
//! ## Determinism
//!
//! Everything is single-threaded and seeded: events tie-break on a
//! monotone sequence number, link randomness comes from one derived
//! [`Pcg`] consumed in event order, and per-directed-edge delivery is
//! clamped FIFO.  Same seed ⇒ bit-identical
//! [`Report`](crate::coordinator::Report) — the property the replay
//! tests pin, and what makes simulator bugs reproducible from a single
//! `u64`.
//!
//! ## Local compute
//!
//! The numerics of the K local steps run through a [`LocalUpdate`]
//! backend: the PJRT CNN runtime when AOT artifacts exist (see
//! `coordinator::run_with_engine`), or the artifact-free
//! [`SoftmaxLocal`] otherwise — which is how CI exercises 512-node
//! rings with zero Python or XLA in the loop.  Virtual compute time is
//! `compute_ns_per_step × K × straggler_factor`; evaluation is timed at
//! zero virtual cost (it is reporting, not protocol).

pub mod link;
pub mod softmax;

pub use link::{
    BandwidthLink, ConstantLatency, IdealLink, LinkModel, LinkSpec,
    LossyLink, Transmission,
};
pub use softmax::SoftmaxLocal;

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::algorithms::{NodeStateMachine, RoundPolicy};
use crate::comm::{directed_edge_index, CommError, Envelope, Meter, Msg, Outbox};
use crate::graph::{ChurnSchedule, Graph, TopologyView};
use crate::metrics::{EpochRecord, History, Mean};
use crate::util::rng::{streams, Pcg};

/// Scenario knobs for one simulated run.  Lives inside
/// `ExperimentSpec` (via `ExecMode::Simulated`), so it stays
/// `Clone + Debug`.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub link: LinkSpec,
    /// Heterogeneous links: per-edge overrides `(edge_index, spec)`;
    /// unlisted edges use `link`.  One topology can mix fast and slow
    /// edges — the regime where async rounds shine (a slow edge lags
    /// instead of stalling the whole graph).
    pub edge_links: Vec<(usize, LinkSpec)>,
    /// Virtual nanoseconds one local step costs on a nominal node.
    pub compute_ns_per_step: u64,
    /// Per-node compute slowdown factors `(node, factor)`; factor 2.0
    /// means the node computes at half speed.  Unlisted nodes run at 1.0.
    pub stragglers: Vec<(usize, f64)>,
    /// Time-varying topology: state-preserving outage windows plus
    /// state-tearing edge churn / node join-leave (empty = static,
    /// pinned bit-identical to the pre-churn engine).
    pub churn: ChurnSchedule,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            link: LinkSpec::Ideal,
            edge_links: Vec::new(),
            compute_ns_per_step: 1_000_000, // 1 ms per local step
            stragglers: Vec::new(),
            churn: ChurnSchedule::default(),
        }
    }
}

/// Round/eval bookkeeping shared by both execution engines.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub epochs: usize,
    pub rounds_per_epoch: usize,
    /// K — local steps per round (used for virtual compute time).
    pub local_steps: usize,
    /// `last round index of epoch -> epoch`, for epochs that evaluate.
    pub eval_rounds: BTreeMap<usize, usize>,
}

impl Schedule {
    pub fn new(epochs: usize, rounds_per_epoch: usize, local_steps: usize,
               eval_every: usize) -> Schedule {
        let eval_every = eval_every.max(1);
        let eval_rounds = (1..=epochs)
            .filter(|e| e % eval_every == 0 || *e == epochs)
            .map(|e| (e * rounds_per_epoch - 1, e))
            .collect();
        Schedule {
            epochs,
            rounds_per_epoch,
            local_steps,
            eval_rounds,
        }
    }

    pub fn total_rounds(&self) -> usize {
        self.epochs * self.rounds_per_epoch
    }
}

/// The numerics of the K local steps between exchanges, behind a trait
/// so the engine is agnostic to PJRT vs native backends.
pub trait LocalUpdate: Send {
    /// Run the K local steps preceding exchange round `round`, mutating
    /// `w` in place.  Returns the mean train loss over the steps.
    fn local_round(&mut self, round: usize, w: &mut [f32], zsum: &[f32],
                   alpha_deg: f32) -> Result<f64>;

    /// Full test evaluation: `(accuracy, mean loss)`.
    fn evaluate(&mut self, w: &[f32]) -> Result<(f64, f64)>;
}

/// No-op local model for exchange-only simulations (protocol tests and
/// byte-accounting equivalence against the threaded bus).
pub struct NullLocal;

impl LocalUpdate for NullLocal {
    fn local_round(&mut self, _round: usize, _w: &mut [f32], _zsum: &[f32],
                   _alpha_deg: f32) -> Result<f64> {
        Ok(0.0)
    }

    fn evaluate(&mut self, _w: &[f32]) -> Result<(f64, f64)> {
        Ok((0.0, 0.0))
    }
}

/// One node handed to [`simulate`]: protocol + local numerics + initial
/// parameters.
pub struct NodeSetup {
    pub machine: Box<dyn NodeStateMachine>,
    pub local: Box<dyn LocalUpdate>,
    pub w: Vec<f32>,
}

/// What a simulated run produces.
pub struct SimOutcome {
    pub history: History,
    /// Virtual time at which the last event fired.
    pub vtime_ns: u64,
    pub meter: Arc<Meter>,
    /// Final per-node parameters.
    pub w: Vec<Vec<f32>>,
    /// Largest per-edge staleness (in rounds) of any received message
    /// a node consumed — 0 under `RoundPolicy::Sync`, `≤ max_staleness`
    /// under `Async` (the bound is enforced in-protocol and pinned by
    /// tests; start-up slack on silent edges is not counted).
    pub max_staleness: usize,
    /// Edge lifecycle transitions (kills + revivals) applied by the
    /// churn scheduler — 0 on a static schedule.  The meter separately
    /// counts `churn_dropped_frames`/`churn_dropped_bytes` for frames
    /// drained in flight.
    pub edges_churned: u64,
}

// ---------------------------------------------------------------------
// Event queue
// ---------------------------------------------------------------------

#[derive(Debug)]
enum EventKind {
    /// Node finished its K local steps and enters the exchange phase.
    ComputeDone { node: usize },
    /// A message reaches its destination.
    Deliver { env: Envelope },
    /// A churn-schedule transition boundary: re-derive edge liveness,
    /// update the topology view, notify affected machines, re-poll
    /// their gates, and schedule the next boundary.
    Churn,
}

#[derive(Debug)]
struct Event {
    t_ns: u64,
    /// Monotone tie-breaker: equal-time events fire in schedule order,
    /// which both guarantees determinism and per-edge FIFO.
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t_ns == other.t_ns && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.t_ns
            .cmp(&other.t_ns)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

/// Min-heap wrapper (BinaryHeap is a max-heap).
struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
    seq: u64,
}

impl EventQueue {
    fn new() -> EventQueue {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    fn push(&mut self, t_ns: u64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(std::cmp::Reverse(Event {
            t_ns,
            seq: self.seq,
            kind,
        }));
    }

    fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|std::cmp::Reverse(e)| e)
    }
}

// ---------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------

/// Message transport: meters payloads, draws link outcomes, queues
/// serialization per directed edge (a serial link sends one message at
/// a time — back-to-back, never in parallel), enforces FIFO delivery,
/// and schedules `Deliver` events.
struct Courier<'a> {
    graph: &'a Graph,
    churn: &'a ChurnSchedule,
    link: Box<dyn LinkModel>,
    /// Heterogeneous-link overrides keyed by undirected edge index;
    /// edges not listed fall back to `link`.
    edge_links: BTreeMap<usize, Box<dyn LinkModel>>,
    link_rng: Pcg,
    meter: &'a Meter,
    queue: EventQueue,
    /// When each directed edge finishes serializing its last queued
    /// message — the earliest the next one may start.
    busy_until: BTreeMap<(usize, usize), u64>,
    /// Last scheduled arrival per directed edge — delivery never
    /// reorders within an edge (TCP-like semantics the protocols rely
    /// on).  With per-edge-constant latency this follows from the
    /// departure queue already; kept as a defensive clamp.
    last_arrival: BTreeMap<(usize, usize), u64>,
}

impl Courier<'_> {
    fn send(&mut self, src: usize, dst: usize, round: usize, msg: Msg,
            now: u64, view: &TopologyView) -> Result<()> {
        let edge = self
            .graph
            .edge_index(src, dst)
            .ok_or_else(|| anyhow!("sim: ({src}, {dst}) is not an edge"))?;
        let bytes = msg.wire_bytes();
        self.meter.record_send(src, bytes);
        self.meter
            .record_edge_send(directed_edge_index(edge, src, dst), bytes as u64);
        let life = view.edge_life(edge);
        if !life.live {
            // Defensive: a send raced an edge removal.  The first-copy
            // bytes stay metered (the transmission happened), the frame
            // vanishes as a typed churn drop.
            self.meter.record_churn_drop(bytes as u64);
            return Ok(());
        }
        let model = self
            .edge_links
            .get(&edge)
            .map(|m| m.as_ref())
            .unwrap_or(self.link.as_ref());
        let tx = model.transmit(bytes, &mut self.link_rng);
        if tx.attempts > 1 {
            self.meter.record_retransmit(src, tx.retransmit_bytes(bytes));
        }
        // Serialization starts when the edge is up AND free: an
        // outage-held edge delays the message until the window ends,
        // and a busy edge queues it behind the previous message.
        let start = self
            .churn
            .outage_next_up(edge, now)
            .max(*self.busy_until.get(&(src, dst)).unwrap_or(&0));
        let departure = start.saturating_add(tx.occupancy_ns);
        self.busy_until.insert((src, dst), departure);
        let mut arrival = departure.saturating_add(tx.latency_ns);
        let last = self.last_arrival.entry((src, dst)).or_insert(0);
        if arrival < *last {
            arrival = *last;
        }
        *last = arrival;
        self.queue.push(
            arrival,
            EventKind::Deliver {
                env: Envelope {
                    src,
                    dst,
                    round,
                    epoch: life.epoch,
                    payload: msg,
                },
            },
        );
        Ok(())
    }
}

struct NodeRt {
    machine: Box<dyn NodeStateMachine>,
    local: Box<dyn LocalUpdate>,
    w: Vec<f32>,
    round: usize,
    exchanging: bool,
    /// Per-source FIFO buffers for messages the machine is not ready
    /// for yet (future rounds, or arrivals during local compute).
    inbox: BTreeMap<usize, VecDeque<Envelope>>,
    train_loss: Mean,
    done: bool,
}

struct World<'a> {
    sched: &'a Schedule,
    policy: RoundPolicy,
    rt: Vec<NodeRt>,
    courier: Courier<'a>,
    /// The engine's live topology snapshot (version 0 = static full
    /// view; machines key their lifecycle off its per-edge epochs).
    view: TopologyView,
    churn: &'a ChurnSchedule,
    /// Per-epoch eval slots, filled as nodes reach the epoch boundary.
    evals: BTreeMap<usize, Vec<Option<(f64, f64, f64)>>>,
    history: History,
    compute_ns: Vec<u64>,
    zeros: Vec<f32>,
    finished: usize,
    n: usize,
    total_rounds: usize,
    verbose: bool,
}

impl World<'_> {
    fn on_compute_done(&mut self, i: usize, now: u64) -> Result<()> {
        let round;
        let outv: Vec<(usize, Msg)>;
        {
            let nrt = &mut self.rt[i];
            round = nrt.round;
            let alpha_deg = nrt.machine.alpha_deg();
            let loss = match nrt.machine.zsum() {
                Some(z) => {
                    nrt.local.local_round(round, &mut nrt.w, z, alpha_deg)?
                }
                None => nrt.local.local_round(round, &mut nrt.w, &self.zeros,
                                              alpha_deg)?,
            };
            nrt.train_loss.add(loss);
            let mut out = Outbox::new();
            nrt.machine
                .round_begin(round, &self.view, &mut nrt.w, &mut out)?;
            nrt.exchanging = true;
            outv = out.drain().collect();
        }
        for (to, msg) in outv {
            self.courier.send(i, to, round, msg, now, &self.view)?;
        }
        // Drain anything that arrived while computing; `pump` finishes
        // the round once the policy is satisfied and nothing more is
        // deliverable (degenerate rounds — SGD, degree 0, async slack
        // within the staleness budget — complete without traffic).
        self.pump(i, now)
    }

    fn on_deliver(&mut self, env: Envelope, now: u64) -> Result<()> {
        let dst = env.dst;
        ensure!(dst < self.rt.len(), "sim: delivery to unknown node {dst}");
        // A frame that was in flight across a churn event drains as a
        // typed drop: its edge is gone, or reborn into a different
        // incarnation than the one it was encoded for.
        if let Some(edge) = self.courier.graph.edge_index(env.src, dst) {
            let life = self.view.edge_life(edge);
            if !life.live || life.epoch != env.epoch {
                self.courier
                    .meter
                    .record_churn_drop(env.payload.wire_bytes() as u64);
                if self.verbose {
                    println!(
                        "[sim] {}",
                        CommError::ChurnDropped { src: env.src, dst, edge }
                    );
                }
                return Ok(());
            }
        }
        self.rt[dst].inbox.entry(env.src).or_default().push_back(env);
        if self.rt[dst].exchanging {
            self.pump(dst, now)?;
        }
        Ok(())
    }

    /// Apply the churn schedule's edge liveness at `now`: kill edges
    /// that churned down (purging their buffered frames as typed
    /// drops), revive edges that came back (fresh incarnation,
    /// activating at `1 + max(endpoint rounds)` so both endpoints open
    /// it at the same round number), then notify every affected machine
    /// and re-poll its gate — a node that was waiting on a now-dead
    /// edge completes its round here instead of deadlocking.
    fn apply_churn(&mut self, now: u64) -> Result<()> {
        let edges: Vec<(usize, usize)> =
            self.courier.graph.edges().to_vec();
        let mut affected: std::collections::BTreeSet<usize> =
            std::collections::BTreeSet::new();
        for (e, &(i, j)) in edges.iter().enumerate() {
            let down = self.churn.churned_down(e, i, j, now);
            let life = self.view.edge_life(e);
            if life.live && down {
                self.view.kill_edge(e);
                self.courier.meter.record_edge_churn();
                // Purge frames already delivered into inbox buffers:
                // in-flight state of a dead edge drains as drops.
                for (a, b) in [(i, j), (j, i)] {
                    if let Some(q) = self.rt[b].inbox.get_mut(&a) {
                        for env in q.drain(..) {
                            self.courier.meter.record_churn_drop(
                                env.payload.wire_bytes() as u64,
                            );
                        }
                    }
                }
                affected.insert(i);
                affected.insert(j);
            } else if !life.live && !down {
                let activation =
                    1 + self.rt[i].round.max(self.rt[j].round);
                self.view.revive_edge(e, activation);
                self.courier.meter.record_edge_churn();
                affected.insert(i);
                affected.insert(j);
            }
        }
        for &i in &affected {
            let outv: Vec<(usize, Msg)> = {
                let nrt = &mut self.rt[i];
                let mut out = Outbox::new();
                nrt.machine.on_topology(&self.view, &mut nrt.w, &mut out)?;
                out.drain().collect()
            };
            let round = self.rt[i].round;
            for (to, msg) in outv {
                self.courier.send(i, to, round, msg, now, &self.view)?;
            }
            if self.rt[i].exchanging {
                self.pump(i, now)?;
            }
        }
        Ok(())
    }

    /// Feed buffered messages into the node's machine, then finish the
    /// round once the policy is satisfied and nothing more is
    /// deliverable.  Delivery admission is the round policy's job:
    /// `Sync` holds every message until the receiver's round matches
    /// its stamp (the classic barrier — byte- and trajectory-identical
    /// to the threaded bus), `Async` hands over each per-edge FIFO
    /// head immediately, whatever round it was sent in — the machine
    /// folds in every message it has (the freshest state per edge)
    /// before its local step.
    fn pump(&mut self, i: usize, now: u64) -> Result<()> {
        loop {
            if !self.rt[i].exchanging {
                return Ok(());
            }
            let round = self.rt[i].round;
            let mut found: Option<usize> = None;
            for (&src, q) in self.rt[i].inbox.iter() {
                if let Some(env) = q.front() {
                    match self.policy {
                        RoundPolicy::Sync => {
                            ensure!(
                                env.round >= round,
                                "sim: node {i} holds a stale round-{} message \
                                 from {src} while in round {round}",
                                env.round
                            );
                            if env.round == round {
                                found = Some(src);
                                break;
                            }
                        }
                        RoundPolicy::Async { .. } => {
                            found = Some(src);
                            break;
                        }
                    }
                }
            }
            let Some(src) = found else {
                // Nothing (more) deliverable: step if the policy allows.
                // Under sync this fires exactly when all of this round's
                // messages are in (one per edge — the classic barrier);
                // under async also on slack within the staleness budget.
                if self.rt[i].machine.round_complete() {
                    self.finish_round(i, now)?;
                }
                return Ok(());
            };
            let env = self.rt[i]
                .inbox
                .get_mut(&src)
                .and_then(|q| q.pop_front())
                .expect("front just observed");
            let outv: Vec<(usize, Msg)>;
            {
                let nrt = &mut self.rt[i];
                let mut out = Outbox::new();
                // The machine receives the SENDER's round stamp; its own
                // round only gates completion.
                nrt.machine
                    .on_message(env.round, src, env.payload, &self.view,
                                &mut nrt.w, &mut out)?;
                outv = out.drain().collect();
            }
            for (to, msg) in outv {
                self.courier.send(i, to, round, msg, now, &self.view)?;
            }
        }
    }

    fn finish_round(&mut self, i: usize, now: u64) -> Result<()> {
        let round;
        {
            let nrt = &mut self.rt[i];
            round = nrt.round;
            nrt.machine.round_end(round, &self.view, &mut nrt.w)?;
            nrt.exchanging = false;
        }
        if let Some(&epoch) = self.sched.eval_rounds.get(&round) {
            let (acc, loss) = {
                let nrt = &mut self.rt[i];
                nrt.local.evaluate(&nrt.w)?
            };
            let tl = self.rt[i].train_loss.take();
            let n = self.n;
            let full = {
                let slots = self
                    .evals
                    .entry(epoch)
                    .or_insert_with(|| vec![None; n]);
                ensure!(slots[i].is_none(), "node {i} evaluated epoch {epoch} twice");
                slots[i] = Some((acc, loss, tl));
                slots.iter().all(Option::is_some)
            };
            if full {
                let slots = self.evals.remove(&epoch).expect("just filled");
                let (mut a, mut l, mut t) =
                    (Mean::default(), Mean::default(), Mean::default());
                for s in slots.into_iter().flatten() {
                    a.add(s.0);
                    l.add(s.1);
                    t.add(s.2);
                }
                let rec = EpochRecord {
                    epoch,
                    mean_accuracy: a.take(),
                    mean_loss: l.take(),
                    train_loss: t.take(),
                    cum_bytes_per_node: self.courier.meter.mean_bytes_per_node(),
                    sim_time_secs: now as f64 / 1e9,
                };
                if self.verbose {
                    println!(
                        "[sim] epoch {:>4}: acc {:.3} loss {:.3} train {:.3} \
                         sent/node {:.0} KB  t={:.3}s",
                        rec.epoch,
                        rec.mean_accuracy,
                        rec.mean_loss,
                        rec.train_loss,
                        rec.cum_bytes_per_node / 1024.0,
                        rec.sim_time_secs
                    );
                }
                self.history.push(rec);
            }
        }
        let done = {
            let nrt = &mut self.rt[i];
            nrt.round += 1;
            nrt.round >= self.total_rounds
        };
        if done {
            self.rt[i].done = true;
            self.finished += 1;
        } else {
            let dt = self.compute_ns[i];
            self.courier
                .queue
                .push(now.saturating_add(dt), EventKind::ComputeDone { node: i });
        }
        Ok(())
    }
}

/// Run `sched.total_rounds()` rounds of the given per-node protocols in
/// virtual time under the given round policy (which must match the
/// policy the machines were built with).  Returns the aggregated
/// history, final parameters, and the byte/retransmit/virtual-time
/// meter.
pub fn simulate(
    graph: &Graph,
    cfg: &SimConfig,
    seed: u64,
    sched: &Schedule,
    nodes: Vec<NodeSetup>,
    policy: RoundPolicy,
    verbose: bool,
) -> Result<SimOutcome> {
    let n = graph.n();
    ensure!(n > 0, "sim: empty graph");
    ensure!(
        nodes.len() == n,
        "sim: {} node setups for a {n}-node graph",
        nodes.len()
    );
    cfg.link.validate()?;
    let mut edge_links: BTreeMap<usize, Box<dyn LinkModel>> = BTreeMap::new();
    for (edge, spec) in &cfg.edge_links {
        ensure!(
            *edge < graph.edges().len(),
            "sim: per-edge link for edge {edge}, but the graph has only \
             {} edges",
            graph.edges().len()
        );
        spec.validate()?;
        ensure!(
            edge_links.insert(*edge, spec.build()).is_none(),
            "sim: duplicate per-edge link override for edge {edge}"
        );
    }
    // The engine's delivery policy and each machine's gating policy
    // must agree — a mismatch would surface later as confusing
    // admission errors (or silently mislabel a run).
    for (i, s) in nodes.iter().enumerate() {
        if let Some(p) = s.machine.policy() {
            ensure!(
                p == policy,
                "sim: node {i} was built for `{}` rounds but the engine \
                 is driving `{}`",
                p.name(),
                policy.name()
            );
        }
    }
    // Churn-schedule validation: explicit windows must reference real
    // edges/nodes (typed startup errors, not mid-run index panics).
    if let Some(e) = cfg.churn.max_edge_index() {
        ensure!(
            e < graph.edges().len(),
            "sim: churn window for edge {e}, but the graph has only {} \
             edges",
            graph.edges().len()
        );
    }
    if let Some(node) = cfg.churn.max_node_index() {
        ensure!(node < n, "sim: churn event for node {node} out of range");
    }
    let total_rounds = sched.total_rounds();
    let meter = Meter::with_edges(n, graph.edges().len());
    if total_rounds == 0 {
        let w = nodes.into_iter().map(|s| s.w).collect();
        return Ok(SimOutcome {
            history: History::default(),
            vtime_ns: 0,
            meter,
            w,
            max_staleness: 0,
            edges_churned: 0,
        });
    }

    let d = nodes.iter().map(|s| s.w.len()).max().unwrap_or(0);
    let mut compute_ns =
        vec![cfg.compute_ns_per_step.saturating_mul(sched.local_steps as u64); n];
    let mut straggler_seen = std::collections::BTreeSet::new();
    for &(i, f) in &cfg.stragglers {
        ensure!(i < n, "sim: straggler index {i} out of range");
        ensure!(f > 0.0, "sim: straggler factor must be positive");
        // Like edge_links: a repeated entry would silently compound
        // factors multiplicatively, which is never what it means.
        ensure!(
            straggler_seen.insert(i),
            "sim: duplicate straggler entry for node {i}"
        );
        compute_ns[i] = (compute_ns[i] as f64 * f) as u64;
    }

    let mut world = World {
        sched,
        policy,
        rt: nodes
            .into_iter()
            .map(|s| NodeRt {
                machine: s.machine,
                local: s.local,
                w: s.w,
                round: 0,
                exchanging: false,
                inbox: BTreeMap::new(),
                train_loss: Mean::default(),
                done: false,
            })
            .collect(),
        courier: Courier {
            graph,
            churn: &cfg.churn,
            link: cfg.link.build(),
            edge_links,
            link_rng: Pcg::derive(seed, &[streams::LINK]),
            meter: &meter,
            queue: EventQueue::new(),
            busy_until: BTreeMap::new(),
            last_arrival: BTreeMap::new(),
        },
        view: TopologyView::full(graph.edges().len()),
        churn: &cfg.churn,
        evals: BTreeMap::new(),
        history: History::default(),
        compute_ns,
        zeros: vec![0.0; d],
        finished: 0,
        n,
        total_rounds,
        verbose,
    };

    // Apply the schedule's t = 0 state (edges absent from the start,
    // nodes that join later) before anyone computes, then arm the first
    // transition boundary as a first-class event.
    if cfg.churn.has_churn() {
        world.apply_churn(0)?;
        if let Some(t) = cfg.churn.next_transition_after(0) {
            world.courier.queue.push(t, EventKind::Churn);
        }
    }

    // Every node starts its round-0 local compute at t = 0.
    for i in 0..n {
        let dt = world.compute_ns[i];
        world.courier.queue.push(dt, EventKind::ComputeDone { node: i });
    }

    // Guard against a churn-only spin: the random rule schedules slot
    // boundaries forever, so if nothing but churn events fire for a
    // very long stretch the run is deadlocked — report it instead of
    // looping silently.
    let mut churn_streak = 0u64;
    let mut final_t = 0u64;
    while let Some(ev) = world.courier.queue.pop() {
        final_t = ev.t_ns;
        match ev.kind {
            EventKind::ComputeDone { node } => {
                churn_streak = 0;
                world.on_compute_done(node, ev.t_ns)?
            }
            EventKind::Deliver { env } => {
                churn_streak = 0;
                world.on_deliver(env, ev.t_ns)?
            }
            EventKind::Churn => {
                churn_streak += 1;
                ensure!(
                    churn_streak < 200_000,
                    "sim deadlock: {churn_streak} consecutive churn \
                     events with no protocol progress"
                );
                world.apply_churn(ev.t_ns)?;
                // Keep the boundary clock armed while work remains.
                if world.finished < world.n {
                    if let Some(t) =
                        cfg.churn.next_transition_after(ev.t_ns)
                    {
                        world.courier.queue.push(t, EventKind::Churn);
                    }
                }
            }
        }
    }
    let stuck: Vec<(usize, usize, bool)> = world
        .rt
        .iter()
        .enumerate()
        .filter(|(_, r)| !r.done)
        .map(|(i, r)| (i, r.round, r.exchanging))
        .take(8)
        .collect();
    ensure!(
        world.finished == n,
        "sim deadlock: {}/{} nodes finished; stuck (node, round, \
         exchanging): {:?}",
        world.finished,
        n,
        stuck
    );
    meter.advance_vtime_ns(final_t);
    let World { rt, history, .. } = world;
    let max_staleness = rt
        .iter()
        .map(|r| r.machine.max_staleness_seen())
        .max()
        .unwrap_or(0);
    let w = rt.into_iter().map(|r| r.w).collect();
    let edges_churned = meter.edges_churned();
    Ok(SimOutcome {
        history,
        vtime_ns: meter.vtime_ns(),
        meter,
        w,
        max_staleness,
        edges_churned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{build_machine, AlgorithmSpec, BuildCtx, DualPath};
    use crate::model::DatasetManifest;

    fn machine_setup(
        graph: &Arc<Graph>,
        alg: &AlgorithmSpec,
        seed: u64,
        rounds_per_epoch: usize,
    ) -> Vec<NodeSetup> {
        machine_setup_policy(graph, alg, seed, rounds_per_epoch,
                             RoundPolicy::Sync)
    }

    fn machine_setup_policy(
        graph: &Arc<Graph>,
        alg: &AlgorithmSpec,
        seed: u64,
        rounds_per_epoch: usize,
        round_policy: RoundPolicy,
    ) -> Vec<NodeSetup> {
        let ds = DatasetManifest::synthetic_linear("t", (2, 2, 1), 3, 2, 2);
        (0..graph.n())
            .map(|node| {
                let ctx = BuildCtx {
                    node,
                    graph: Arc::clone(graph),
                    manifest: ds.clone(),
                    seed,
                    eta: 0.05,
                    local_steps: 1,
                    rounds_per_epoch,
                    dual_path: DualPath::Native,
                    runtime: None,
                    round_policy,
                };
                let mut rng = Pcg::new(900 + node as u64);
                let w = (0..ds.d_pad).map(|_| rng.normal_f32()).collect();
                NodeSetup {
                    machine: build_machine(alg, &ctx).unwrap(),
                    local: Box::new(NullLocal),
                    w,
                }
            })
            .collect()
    }

    #[test]
    fn event_ordering_time_then_seq() {
        let mut q = EventQueue::new();
        q.push(50, EventKind::ComputeDone { node: 5 });
        q.push(10, EventKind::ComputeDone { node: 1 });
        q.push(10, EventKind::ComputeDone { node: 2 });
        let order: Vec<(u64, usize)> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EventKind::ComputeDone { node } => (e.t_ns, node),
                _ => unreachable!(),
            })
            .collect();
        // Time first; equal times in push (seq) order.
        assert_eq!(order, vec![(10, 1), (10, 2), (50, 5)]);
    }

    #[test]
    fn schedule_eval_rounds() {
        let s = Schedule::new(7, 4, 5, 3);
        assert_eq!(s.total_rounds(), 28);
        // Epochs 3, 6, 7 evaluate, at the last round of each.
        let expect: BTreeMap<usize, usize> =
            [(11, 3), (23, 6), (27, 7)].into_iter().collect();
        assert_eq!(s.eval_rounds, expect);
        assert_eq!(s.local_steps, 5);
    }

    #[test]
    fn two_node_exchange_virtual_clock() {
        // chain(2), ECL dense, 1 round: local compute takes 1000 ns,
        // the constant link 1 us, so the run ends at exactly 2000 ns.
        let graph = Arc::new(Graph::chain(2));
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 1_000,
            ..SimConfig::default()
        };
        let sched = Schedule::new(1, 1, 1, 1);
        let alg = AlgorithmSpec::Ecl { theta: 1.0 };
        let nodes = machine_setup(&graph, &alg, 7, 1);
        let out = simulate(&graph, &cfg, 7, &sched, nodes, RoundPolicy::Sync,
                           false).unwrap();
        // sends fire at t=1000, arrive at t=2000.
        assert_eq!(out.vtime_ns, 2_000);
        // ECL dense: d floats both ways.
        let d = DatasetManifest::synthetic_linear("t", (2, 2, 1), 3, 2, 2).d;
        assert_eq!(out.meter.total_bytes() as usize, 2 * 4 * d);
        assert_eq!(out.meter.total_retransmit_bytes(), 0);
    }

    #[test]
    fn straggler_stretches_virtual_time() {
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(2, 2, 1, 1);
        let alg = AlgorithmSpec::DPsgd;
        let base_cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 100_000,
            ..SimConfig::default()
        };
        let slow_cfg = SimConfig {
            stragglers: vec![(2, 8.0)],
            ..base_cfg.clone()
        };
        let fast = simulate(&graph, &base_cfg, 3, &sched,
                            machine_setup(&graph, &alg, 3, 2),
                            RoundPolicy::Sync, false)
            .unwrap();
        let slow = simulate(&graph, &slow_cfg, 3, &sched,
                            machine_setup(&graph, &alg, 3, 2),
                            RoundPolicy::Sync, false)
            .unwrap();
        assert!(slow.vtime_ns > fast.vtime_ns * 4,
                "straggler {} vs {}", slow.vtime_ns, fast.vtime_ns);
        // Same traffic either way.
        assert_eq!(slow.meter.total_bytes(), fast.meter.total_bytes());
        // Repeated straggler entries would compound silently — rejected.
        let dup_cfg = SimConfig {
            stragglers: vec![(2, 2.0), (2, 2.0)],
            ..base_cfg
        };
        let err = simulate(&graph, &dup_cfg, 3, &sched,
                           machine_setup(&graph, &alg, 3, 2),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("duplicate straggler"), "{err}");
    }

    #[test]
    fn outage_holds_messages_until_edge_recovers() {
        let graph = Arc::new(Graph::chain(2));
        let sched = Schedule::new(1, 1, 1, 1);
        let alg = AlgorithmSpec::Ecl { theta: 1.0 };
        let mut churn = ChurnSchedule::default();
        // Edge 0 in OUTAGE from t=0 until t=5 ms: round-0 sends (at
        // ~1 us) stall until the window ends — held, never dropped,
        // with zero topology transitions (state-preserving semantics).
        churn.add_outage(0, 0, 5_000_000);
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 1_000,
            churn,
            ..SimConfig::default()
        };
        let out = simulate(&graph, &cfg, 11, &sched,
                           machine_setup(&graph, &alg, 11, 1),
                           RoundPolicy::Sync, false)
            .unwrap();
        assert!(out.vtime_ns >= 5_000_000, "vtime {}", out.vtime_ns);
        assert_eq!(out.edges_churned, 0, "outage is not churn");
        assert_eq!(out.meter.churn_dropped_frames(), 0);
        let no_outage = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 1_000,
            ..SimConfig::default()
        };
        let base = simulate(&graph, &no_outage, 11, &sched,
                            machine_setup(&graph, &alg, 11, 1),
                            RoundPolicy::Sync, false)
            .unwrap();
        assert!(base.vtime_ns < out.vtime_ns);
    }

    #[test]
    fn churn_removes_edge_drops_in_flight_and_revives_fresh() {
        // ring(3), C-ECL sync.  Edge 0 = (0, 1) churns out over rounds
        // 1..2 and comes back: the run completes, the in-flight frames
        // of the removal window drain as typed drops (byte-exact: sends
        // stay metered), and the lifecycle counter sees both the kill
        // and the revival.
        let graph = Arc::new(Graph::ring(3));
        let sched = Schedule::new(6, 1, 1, 6);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.5,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let mut churn = ChurnSchedule::default();
        // Compute = 100 us/round, latency 10 us: round-0 frames are in
        // flight during (100, 110) us, so a kill at 105 us catches them
        // mid-air — they MUST drain as typed drops, and the churn event
        // must unblock the endpoints that were waiting on them.
        churn.add_edge_down(0, 105_000, 350_000);
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 10 },
            compute_ns_per_step: 100_000,
            churn,
            ..SimConfig::default()
        };
        let out = simulate(&graph, &cfg, 5, &sched,
                           machine_setup(&graph, &alg, 5, 1),
                           RoundPolicy::Sync, false)
            .unwrap();
        assert_eq!(out.edges_churned, 2, "one kill + one revival");
        assert!(out.meter.churn_dropped_frames() > 0,
                "in-flight frames must drain as drops");
        assert!(out.meter.churn_dropped_bytes() > 0);
        // Replay determinism with churn in the schedule.
        let out2 = simulate(&graph, &cfg, 5, &sched,
                            machine_setup(&graph, &alg, 5, 1),
                            RoundPolicy::Sync, false)
            .unwrap();
        assert_eq!(out.meter.total_bytes(), out2.meter.total_bytes());
        assert_eq!(out.meter.churn_dropped_frames(),
                   out2.meter.churn_dropped_frames());
        assert_eq!(out.w, out2.w, "churn replay must be bit-identical");
    }

    #[test]
    fn node_leave_and_join_complete_without_panics() {
        // Node 2 leaves a ring(4) mid-run (all its edges churn out);
        // node 3 joins late (absent from t=0).  Both engines' gates
        // skip dead edges, so every node still finishes its rounds.
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(6, 1, 1, 6);
        let alg = AlgorithmSpec::DPsgd;
        let mut churn = ChurnSchedule::default();
        churn.add_node_leave(2, 400_000);
        churn.add_node_join(3, 250_000);
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 10 },
            compute_ns_per_step: 100_000,
            churn,
            ..SimConfig::default()
        };
        let out = simulate(&graph, &cfg, 9, &sched,
                           machine_setup(&graph, &alg, 9, 1),
                           RoundPolicy::Sync, false)
            .unwrap();
        assert!(out.edges_churned >= 4, "join + leave must transition");
        assert_eq!(out.history.records.len(), 1, "final epoch still evals");
        // Bad schedules are typed startup errors.
        let mut bad = ChurnSchedule::default();
        bad.add_edge_down(99, 0, 10);
        let cfg_bad = SimConfig {
            churn: bad,
            ..SimConfig::default()
        };
        let err = simulate(&graph, &cfg_bad, 9, &sched,
                           machine_setup(&graph, &alg, 9, 1),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("edge 99"), "{err}");
        let mut bad = ChurnSchedule::default();
        bad.add_node_leave(7, 10);
        let cfg_bad = SimConfig {
            churn: bad,
            ..SimConfig::default()
        };
        let err = simulate(&graph, &cfg_bad, 9, &sched,
                           machine_setup(&graph, &alg, 9, 1),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("node 7"), "{err}");
    }

    #[test]
    fn replay_is_bit_identical() {
        let graph = Arc::new(Graph::ring(5));
        let sched = Schedule::new(2, 3, 2, 1);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.4,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let cfg = SimConfig {
            link: LinkSpec::Lossy {
                latency_us: 50,
                mbit_per_sec: 100.0,
                drop_p: 0.3,
            },
            ..SimConfig::default()
        };
        let a = simulate(&graph, &cfg, 21, &sched,
                         machine_setup(&graph, &alg, 21, 3),
                         RoundPolicy::Sync, false)
            .unwrap();
        let b = simulate(&graph, &cfg, 21, &sched,
                         machine_setup(&graph, &alg, 21, 3),
                         RoundPolicy::Sync, false)
            .unwrap();
        assert_eq!(a.vtime_ns, b.vtime_ns);
        assert_eq!(a.meter.total_bytes(), b.meter.total_bytes());
        assert_eq!(
            a.meter.total_retransmit_bytes(),
            b.meter.total_retransmit_bytes()
        );
        assert_eq!(a.w, b.w, "final parameters must replay bit-identically");
        assert!(a.meter.total_retransmit_bytes() > 0, "p=0.3 must retransmit");
    }

    #[test]
    fn per_edge_link_override_slows_only_its_edge() {
        // chain(3): edges 0 = (0,1), 1 = (1,2).  Overriding edge 1 with
        // a high-latency link must stretch virtual time; overriding a
        // third, nonexistent edge must be rejected.
        let graph = Arc::new(Graph::chain(3));
        let sched = Schedule::new(1, 1, 1, 1);
        let alg = AlgorithmSpec::Ecl { theta: 1.0 };
        let base = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 1_000,
            ..SimConfig::default()
        };
        let hetero = SimConfig {
            edge_links: vec![(1, LinkSpec::Constant { latency_us: 4_000 })],
            ..base.clone()
        };
        let fast = simulate(&graph, &base, 5, &sched,
                            machine_setup(&graph, &alg, 5, 1),
                            RoundPolicy::Sync, false)
            .unwrap();
        let slow = simulate(&graph, &hetero, 5, &sched,
                            machine_setup(&graph, &alg, 5, 1),
                            RoundPolicy::Sync, false)
            .unwrap();
        // Same payload traffic, different clock: only edge 1 slowed.
        assert_eq!(fast.meter.total_bytes(), slow.meter.total_bytes());
        assert_eq!(fast.vtime_ns, 1_000 + 1_000);
        assert_eq!(slow.vtime_ns, 1_000 + 4_000_000);

        let bad = SimConfig {
            edge_links: vec![(7, LinkSpec::Ideal)],
            ..base.clone()
        };
        let err = simulate(&graph, &bad, 5, &sched,
                           machine_setup(&graph, &alg, 5, 1),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("edge 7"), "{err}");
        let dup = SimConfig {
            edge_links: vec![(0, LinkSpec::Ideal), (0, LinkSpec::Ideal)],
            ..base
        };
        let err = simulate(&graph, &dup, 5, &sched,
                           machine_setup(&graph, &alg, 5, 1),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn async_rounds_hide_a_slow_edge_within_staleness() {
        // ring(4) with one 10x-latency edge.  Sync: the whole lockstep
        // ring is throttled through that edge every round.  Async:2 the
        // slow edge lags up to two rounds and everyone else free-runs —
        // strictly less virtual time for the same number of rounds, and
        // the staleness bound is both observed and reached.
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(4, 2, 1, 4);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.4,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 10 },
            edge_links: vec![(0, LinkSpec::Constant { latency_us: 150 })],
            compute_ns_per_step: 100_000,
            ..SimConfig::default()
        };
        let sync = simulate(&graph, &cfg, 3, &sched,
                            machine_setup(&graph, &alg, 3, 2),
                            RoundPolicy::Sync, false)
            .unwrap();
        let policy = RoundPolicy::Async { max_staleness: 2 };
        let async_out = simulate(
            &graph,
            &cfg,
            3,
            &sched,
            machine_setup_policy(&graph, &alg, 3, 2, policy),
            policy,
            false,
        )
        .unwrap();
        assert_eq!(sync.max_staleness, 0, "sync must never lag");
        assert!(async_out.max_staleness >= 1, "slow edge must actually lag");
        assert!(async_out.max_staleness <= 2, "staleness bound violated");
        // Identical payload traffic (every node still sends every
        // round), strictly less virtual time.
        assert_eq!(sync.meter.total_bytes(), async_out.meter.total_bytes());
        assert!(
            async_out.vtime_ns < sync.vtime_ns,
            "async {} !< sync {}",
            async_out.vtime_ns,
            sync.vtime_ns
        );
    }

    #[test]
    fn engine_rejects_policy_mismatch_with_machines() {
        // Machines built for Sync cannot be driven under Async (and
        // vice versa) — a typed startup error, not a mid-run puzzle.
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(1, 1, 1, 1);
        let alg = AlgorithmSpec::DPsgd;
        let err = simulate(
            &graph,
            &SimConfig::default(),
            3,
            &sched,
            machine_setup(&graph, &alg, 3, 1), // built for Sync
            RoundPolicy::Async { max_staleness: 1 },
            false,
        )
        .err()
        .unwrap();
        assert!(err.to_string().contains("built for `sync`"), "{err}");
    }

    #[test]
    fn async_replay_is_bit_identical() {
        let graph = Arc::new(Graph::ring(5));
        let sched = Schedule::new(2, 3, 2, 1);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.4,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let cfg = SimConfig {
            link: LinkSpec::Lossy {
                latency_us: 50,
                mbit_per_sec: 100.0,
                drop_p: 0.3,
            },
            stragglers: vec![(2, 4.0)],
            ..SimConfig::default()
        };
        let policy = RoundPolicy::Async { max_staleness: 3 };
        let run = || {
            simulate(&graph, &cfg, 21, &sched,
                     machine_setup_policy(&graph, &alg, 21, 3, policy),
                     policy, false)
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.vtime_ns, b.vtime_ns);
        assert_eq!(a.meter.total_bytes(), b.meter.total_bytes());
        assert_eq!(a.w, b.w, "async replay must be bit-identical");
        assert_eq!(a.max_staleness, b.max_staleness);
        assert!(a.max_staleness <= 3, "bound violated: {}", a.max_staleness);
    }
}
