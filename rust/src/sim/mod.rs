//! Event-driven virtual-time network simulator — the crate's second
//! execution engine.
//!
//! The threaded coordinator (one OS thread per node, blocking channels)
//! models a perfect network: zero latency, lossless, and it cannot
//! scale past a few dozen nodes or report anything but byte counts.
//! This engine replaces threads with poll-driven state machines
//! ([`NodeStateMachine`](crate::algorithms::NodeStateMachine)) scheduled
//! off a calendar-queue event scheduler keyed by **virtual
//! nanoseconds**:
//!
//! * one machine simulates a million nodes (the scale lever): per-node
//!   scheduler state lives in SoA vectors, per-directed-edge courier
//!   state in a CSR layout, message buffers come from a recycling
//!   frame pool, and the scheduler is O(1) amortized
//!   (`sim::queue::CalendarQueue`),
//! * no thread spawn/park overhead in benches (the speed lever),
//! * messages travel through pluggable [`LinkModel`]s — constant
//!   latency, bandwidth-proportional serialization, i.i.d. drop with
//!   retransmit byte accounting, heterogeneous per-edge overrides
//!   (`SimConfig::edge_links`) — plus per-node straggler slowdowns and
//!   a scheduled [`ChurnSchedule`](crate::graph::ChurnSchedule):
//!   state-preserving edge *outages* (traffic held until the window
//!   ends) and state-tearing *churn* (edge removal / node join-leave),
//!   so *time-to-accuracy* under imperfect networks becomes measurable
//!   (the scenario lever),
//! * topology churn applies at **schedule boundaries**: at every
//!   transition time the engine updates its epoch-stamped
//!   [`TopologyView`](crate::graph::TopologyView), notifies the
//!   affected machines (which retire / warm-start per-edge state), and
//!   re-polls their gates — before any protocol event carrying the
//!   same timestamp.  A removed edge drains its in-flight frames
//!   as typed churn drops (metered, never a panic); a revived edge is a
//!   fresh incarnation activating at `1 + max(endpoint rounds)` so both
//!   endpoints open it at the same round number.  Staleness bounds are
//!   evaluated over currently-live edges only (the churn lever),
//! * rounds follow a [`RoundPolicy`]: the classic bulk-synchronous
//!   barrier (`Sync`, trajectory-identical to the threaded bus), or
//!   gossip-style `Async { max_staleness }` where every message is
//!   delivered the moment it arrives (per-edge FIFO, stamped with the
//!   sender's round) and a node steps once each edge is at most
//!   `max_staleness` rounds stale — a straggler or one slow edge then
//!   delays only its own edges (the async lever),
//! * `SimConfig::threads > 1` runs the same loop as a conservative
//!   parallel discrete-event simulation: contiguous node blocks
//!   (`graph::partition_blocks`), one event queue per block, windows of
//!   `lookahead = min cross-partition link latency` executed fork-join
//!   (the parallel lever — see the crate docs, "Scaling & parallel
//!   simulation").
//!
//! ## Determinism
//!
//! Every run is a pure function of its seed.  Events tie-break on a
//! *structural* key — `(class, src, dst, per-edge FIFO index)`, see
//! `sim::queue` — so the pop order is a property of the event set, not
//! of who pushed first; link randomness is a fresh
//! [`Pcg`] derived per `(directed edge, message index)`, consumed by no
//! one else; per-directed-edge delivery is clamped FIFO.  None of these
//! depend on partition count, which is why `threads: N` replays
//! `threads: 1` bit-for-bit — same trajectories, same byte counters,
//! same [`Report`](crate::coordinator::Report) — and why simulator bugs
//! are reproducible from a single `u64`.
//!
//! ## Local compute
//!
//! The numerics of the K local steps run through a [`LocalUpdate`]
//! backend: the PJRT CNN runtime when AOT artifacts exist (see
//! `coordinator::run_with_engine`), or the artifact-free
//! [`SoftmaxLocal`] otherwise — which is how CI exercises 512-node
//! rings with zero Python or XLA in the loop.  Virtual compute time is
//! `compute_ns_per_step × K × straggler_factor`; evaluation is timed at
//! zero virtual cost (it is reporting, not protocol).

pub mod link;
mod queue;
pub mod softmax;

pub use link::{
    BandwidthLink, ConstantLatency, IdealLink, LinkModel, LinkSpec,
    LossyLink, Transmission,
};
pub use softmax::SoftmaxLocal;

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::algorithms::{NodeStateMachine, RoundPolicy};
use crate::comm::{directed_edge_index, CommError, Envelope, Meter, Msg, Outbox};
use crate::graph::{
    block_owner, partition_blocks, ChurnSchedule, Graph, TopologyView,
};
use crate::metrics::{EpochRecord, History, Mean};
use crate::model::Arena;
use crate::util::rng::{streams, Pcg};

use queue::{CalendarQueue, Event, EventKey, EventKind};

/// Scenario knobs for one simulated run.  Lives inside
/// `ExperimentSpec` (via `ExecMode::Simulated`), so it stays
/// `Clone + Debug`.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub link: LinkSpec,
    /// Heterogeneous links: per-edge overrides `(edge_index, spec)`;
    /// unlisted edges use `link`.  One topology can mix fast and slow
    /// edges — the regime where async rounds shine (a slow edge lags
    /// instead of stalling the whole graph).
    pub edge_links: Vec<(usize, LinkSpec)>,
    /// Virtual nanoseconds one local step costs on a nominal node.
    pub compute_ns_per_step: u64,
    /// Per-node compute slowdown factors `(node, factor)`; factor 2.0
    /// means the node computes at half speed.  Unlisted nodes run at 1.0.
    pub stragglers: Vec<(usize, f64)>,
    /// Time-varying topology: state-preserving outage windows plus
    /// state-tearing edge churn / node join-leave (empty = static,
    /// pinned bit-identical to the pre-churn engine).
    pub churn: ChurnSchedule,
    /// Worker threads for the conservative-parallel loop; 1 (the
    /// default) is the serial engine.  Any value is bit-identical to
    /// serial by construction.  Needs latency on cross-partition links
    /// for a nonzero lookahead window — with zero-latency (ideal)
    /// cross-partition links the engine quietly falls back to serial.
    pub threads: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            link: LinkSpec::Ideal,
            edge_links: Vec::new(),
            compute_ns_per_step: 1_000_000, // 1 ms per local step
            stragglers: Vec::new(),
            churn: ChurnSchedule::default(),
            threads: 1,
        }
    }
}

/// Round/eval bookkeeping shared by both execution engines.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub epochs: usize,
    pub rounds_per_epoch: usize,
    /// K — local steps per round (used for virtual compute time).
    pub local_steps: usize,
    /// `last round index of epoch -> epoch`, for epochs that evaluate.
    pub eval_rounds: BTreeMap<usize, usize>,
}

impl Schedule {
    pub fn new(epochs: usize, rounds_per_epoch: usize, local_steps: usize,
               eval_every: usize) -> Schedule {
        let eval_every = eval_every.max(1);
        let eval_rounds = (1..=epochs)
            .filter(|e| e % eval_every == 0 || *e == epochs)
            .map(|e| (e * rounds_per_epoch - 1, e))
            .collect();
        Schedule {
            epochs,
            rounds_per_epoch,
            local_steps,
            eval_rounds,
        }
    }

    pub fn total_rounds(&self) -> usize {
        self.epochs * self.rounds_per_epoch
    }
}

/// The numerics of the K local steps between exchanges, behind a trait
/// so the engine is agnostic to PJRT vs native backends.
pub trait LocalUpdate: Send {
    /// Run the K local steps preceding exchange round `round`, mutating
    /// `w` in place.  Returns the mean train loss over the steps.
    fn local_round(&mut self, round: usize, w: &mut [f32], zsum: &[f32],
                   alpha_deg: f32) -> Result<f64>;

    /// Full test evaluation: `(accuracy, mean loss)`.
    fn evaluate(&mut self, w: &[f32]) -> Result<(f64, f64)>;
}

/// No-op local model for exchange-only simulations (protocol tests and
/// byte-accounting equivalence against the threaded bus).
pub struct NullLocal;

impl LocalUpdate for NullLocal {
    fn local_round(&mut self, _round: usize, _w: &mut [f32], _zsum: &[f32],
                   _alpha_deg: f32) -> Result<f64> {
        Ok(0.0)
    }

    fn evaluate(&mut self, _w: &[f32]) -> Result<(f64, f64)> {
        Ok((0.0, 0.0))
    }
}

/// One node handed to [`simulate`]: protocol + local numerics + initial
/// parameters.
pub struct NodeSetup {
    pub machine: Box<dyn NodeStateMachine>,
    pub local: Box<dyn LocalUpdate>,
    pub w: Vec<f32>,
}

/// What a simulated run produces.
pub struct SimOutcome {
    pub history: History,
    /// Virtual time at which the last event fired.
    pub vtime_ns: u64,
    pub meter: Arc<Meter>,
    /// Final per-node parameters.
    pub w: Vec<Vec<f32>>,
    /// Largest per-edge staleness (in rounds) of any received message
    /// a node consumed — 0 under `RoundPolicy::Sync`, `≤ max_staleness`
    /// under `Async` (the bound is enforced in-protocol and pinned by
    /// tests; start-up slack on silent edges is not counted).
    pub max_staleness: usize,
    /// Edge lifecycle transitions (kills + revivals) applied by the
    /// churn scheduler — 0 on a static schedule.  The meter separately
    /// counts `churn_dropped_frames`/`churn_dropped_bytes` for frames
    /// drained in flight.
    pub edges_churned: u64,
}

// ---------------------------------------------------------------------
// Engine layout
// ---------------------------------------------------------------------

/// Flattened adjacency (CSR): slot `off[i] + k` is node `i`'s k-th
/// neighbor, with the undirected edge index and the directed edge index
/// (for the per-direction byte meter) precomputed per slot.  Slots are
/// also the index space of the per-directed-edge courier state
/// ([`OutLink`]), replacing the `BTreeMap<(src, dst), _>` lookups of
/// the heap-era engine.
struct Csr {
    off: Vec<usize>,
    nbr: Vec<u32>,
    edge: Vec<u32>,
    dir: Vec<u32>,
}

impl Csr {
    fn build(graph: &Graph) -> Csr {
        let n = graph.n();
        let mut off = Vec::with_capacity(n + 1);
        let mut nbr = Vec::new();
        let mut edge = Vec::new();
        let mut dir = Vec::new();
        off.push(0);
        for i in 0..n {
            for &j in graph.neighbors(i) {
                let e = graph.edge_index(i, j).expect("neighbor without edge");
                nbr.push(j as u32);
                edge.push(e as u32);
                dir.push(directed_edge_index(e, i, j) as u32);
            }
            off.push(nbr.len());
        }
        Csr { off, nbr, edge, dir }
    }
}

/// Per-directed-edge courier state, indexed by CSR slot.
#[derive(Clone, Copy, Default)]
struct OutLink {
    /// When this directed edge finishes serializing its last queued
    /// message — the earliest the next one may start.
    busy_until: u64,
    /// Last scheduled arrival — delivery never reorders within an edge
    /// (TCP-like semantics the protocols rely on).  With per-edge
    /// constant latency this follows from the departure queue already;
    /// kept as a defensive clamp.
    last_arrival: u64,
    /// Messages sent on this directed edge so far: the FIFO index in
    /// the event key and the per-message link-RNG stream index.
    fifo: u64,
}

/// One node's eval at an epoch boundary, recorded where (and when) it
/// happens; the driver folds samples into `EpochRecord`s after the run.
/// `own_bytes` is the node's *own* cumulative send counter at its
/// boundary — a per-node quantity, so it is identical under any
/// partitioning (a global meter snapshot would not be).
struct EvalSample {
    epoch: usize,
    node: usize,
    acc: f64,
    loss: f64,
    train: f64,
    own_bytes: u64,
    t_ns: u64,
}

/// Read-only state every partition shares (all `Sync`: the meter is
/// atomic, the rest is immutable for the duration of a window).
struct Shared<'a> {
    graph: &'a Graph,
    csr: &'a Csr,
    sched: &'a Schedule,
    churn: &'a ChurnSchedule,
    meter: &'a Meter,
    policy: RoundPolicy,
    compute_ns: &'a [u64],
    zeros: &'a [f32],
    /// Block-partition boundaries (`graph::partition_blocks`).
    starts: &'a [usize],
    seed: u64,
    n: usize,
    total_rounds: usize,
    verbose: bool,
}

/// One graph partition: the nodes `lo..hi`, their scheduler state in
/// SoA vectors (indexed `node - lo`), the courier state of every
/// directed edge *originating* here, and this block's event queue.
/// The serial engine is exactly one `Part` spanning `0..n`.
struct Part {
    lo: usize,
    hi: usize,
    machines: Vec<Box<dyn NodeStateMachine>>,
    locals: Vec<Box<dyn LocalUpdate>>,
    /// Per-node parameters as one contiguous slab (SoA arena, row =
    /// partition-local node index) — the round sweep walks memory
    /// linearly instead of chasing one heap box per node.
    ws: Arena,
    rounds: Vec<usize>,
    exchanging: Vec<bool>,
    done: Vec<bool>,
    train_loss: Vec<Mean>,
    /// Per-source FIFO buffers for messages the machine is not ready
    /// for yet (future rounds, or arrivals during local compute);
    /// sorted by source id, mirroring the old `BTreeMap` scan order.
    inboxes: Vec<Vec<(u32, VecDeque<Envelope>)>>,
    /// Courier state for CSR slots `out_base..`, i.e. edges out of
    /// `lo..hi` — every send on a directed edge happens on the
    /// sender's partition, so this state needs no sharing.
    out: Vec<OutLink>,
    out_base: usize,
    link: Box<dyn LinkModel>,
    edge_links: BTreeMap<usize, Box<dyn LinkModel>>,
    queue: CalendarQueue,
    /// Deliveries bound for other partitions, routed by the driver at
    /// the window barrier (always after the current window by the
    /// lookahead bound).
    mail: Vec<Event>,
    finished: usize,
    last_t: u64,
    evals: Vec<EvalSample>,
}

impl Part {
    fn slot_of(&self, sh: &Shared, src: usize, dst: usize) -> Option<usize> {
        (sh.csr.off[src]..sh.csr.off[src + 1])
            .find(|&s| sh.csr.nbr[s] as usize == dst)
    }

    /// Message transport: meters payloads, draws link outcomes from a
    /// per-message derived RNG, queues serialization per directed edge
    /// (a serial link sends one message at a time — back-to-back,
    /// never in parallel), enforces FIFO delivery, and schedules the
    /// `Deliver` event (locally, or via `mail` across partitions).
    fn send(&mut self, sh: &Shared, view: &TopologyView, src: usize,
            dst: usize, round: usize, msg: Msg, now: u64) -> Result<()> {
        let slot = self
            .slot_of(sh, src, dst)
            .ok_or_else(|| anyhow!("sim: ({src}, {dst}) is not an edge"))?;
        let edge = sh.csr.edge[slot] as usize;
        let dir = sh.csr.dir[slot] as usize;
        let bytes = msg.wire_bytes();
        sh.meter.record_send(src, bytes);
        sh.meter.record_edge_send(dir, bytes as u64);
        let life = view.edge_life(edge);
        if !life.live {
            // Defensive: a send raced an edge removal.  The first-copy
            // bytes stay metered (the transmission happened), the frame
            // vanishes as a typed churn drop.
            sh.meter.record_churn_drop(bytes as u64);
            return Ok(());
        }
        let model = self
            .edge_links
            .get(&edge)
            .map(|m| m.as_ref())
            .unwrap_or(self.link.as_ref());
        let ol = &mut self.out[slot - self.out_base];
        let fifo = ol.fifo;
        ol.fifo += 1;
        // One derived stream per (directed edge, message index): link
        // randomness is independent of global event order, hence of
        // partitioning.
        let mut rng =
            Pcg::derive(sh.seed, &[streams::LINK, dir as u64, fifo]);
        let tx = model.transmit(bytes, &mut rng);
        if tx.attempts > 1 {
            sh.meter.record_retransmit(src, tx.retransmit_bytes(bytes));
        }
        // Serialization starts when the edge is up AND free: an
        // outage-held edge delays the message until the window ends,
        // and a busy edge queues it behind the previous message.
        let start = sh.churn.outage_next_up(edge, now).max(ol.busy_until);
        let departure = start.saturating_add(tx.occupancy_ns);
        ol.busy_until = departure;
        let mut arrival = departure.saturating_add(tx.latency_ns);
        if arrival < ol.last_arrival {
            arrival = ol.last_arrival;
        }
        ol.last_arrival = arrival;
        let ev = Event {
            t_ns: arrival,
            key: EventKey::deliver(src, dst, fifo),
            kind: EventKind::Deliver {
                env: Envelope {
                    src,
                    dst,
                    round,
                    epoch: life.epoch,
                    payload: msg,
                },
            },
        };
        if (self.lo..self.hi).contains(&dst) {
            self.queue.push(ev);
        } else {
            self.mail.push(ev);
        }
        Ok(())
    }

    /// Drain this partition's events with `t < end_ns`, in `(t, key)`
    /// order.  Returns the number of events processed.  Safe to run
    /// concurrently with other partitions' windows: the lookahead
    /// bound guarantees no cross-partition event for this window is
    /// still in flight.
    fn run_window(&mut self, sh: &Shared, view: &TopologyView,
                  end_ns: u64) -> Result<u64> {
        let mut count = 0u64;
        while let Some(t) = self.queue.peek_t() {
            if t >= end_ns {
                break;
            }
            let ev = self.queue.pop().expect("peeked nonempty");
            self.last_t = self.last_t.max(ev.t_ns);
            count += 1;
            match ev.kind {
                EventKind::ComputeDone { node } => {
                    self.on_compute_done(sh, view, node, ev.t_ns)?
                }
                EventKind::Deliver { env } => {
                    self.on_deliver(sh, view, env, ev.t_ns)?
                }
            }
        }
        Ok(count)
    }

    fn on_compute_done(&mut self, sh: &Shared, view: &TopologyView,
                       i: usize, now: u64) -> Result<()> {
        let li = i - self.lo;
        let round = self.rounds[li];
        let outv: Vec<(usize, Msg)> = {
            let machine = &mut self.machines[li];
            let alpha_deg = machine.alpha_deg();
            let w = self.ws.row_mut(li);
            let loss = match machine.zsum() {
                Some(z) => {
                    self.locals[li].local_round(round, w, z, alpha_deg)?
                }
                None => self.locals[li].local_round(round, w, sh.zeros,
                                                    alpha_deg)?,
            };
            self.train_loss[li].add(loss);
            let mut out = Outbox::new();
            machine.round_begin(round, view, w, &mut out)?;
            self.exchanging[li] = true;
            out.drain().collect()
        };
        for (to, msg) in outv {
            self.send(sh, view, i, to, round, msg, now)?;
        }
        // Drain anything that arrived while computing; `pump` finishes
        // the round once the policy is satisfied and nothing more is
        // deliverable (degenerate rounds — SGD, degree 0, async slack
        // within the staleness budget — complete without traffic).
        self.pump(sh, view, i, now)
    }

    fn on_deliver(&mut self, sh: &Shared, view: &TopologyView,
                  env: Envelope, now: u64) -> Result<()> {
        let dst = env.dst;
        ensure!(dst < sh.n, "sim: delivery to unknown node {dst}");
        // A frame that was in flight across a churn event drains as a
        // typed drop: its edge is gone, or reborn into a different
        // incarnation than the one it was encoded for.
        if let Some(edge) = sh.graph.edge_index(env.src, dst) {
            let life = view.edge_life(edge);
            if !life.live || life.epoch != env.epoch {
                sh.meter
                    .record_churn_drop(env.payload.wire_bytes() as u64);
                if sh.verbose {
                    println!(
                        "[sim] {}",
                        CommError::ChurnDropped { src: env.src, dst, edge }
                    );
                }
                return Ok(());
            }
        }
        let li = dst - self.lo;
        let src = env.src as u32;
        let inbox = &mut self.inboxes[li];
        match inbox.binary_search_by_key(&src, |&(s, _)| s) {
            Ok(k) => inbox[k].1.push_back(env),
            Err(k) => {
                let mut q = VecDeque::new();
                q.push_back(env);
                inbox.insert(k, (src, q));
            }
        }
        if self.exchanging[li] {
            self.pump(sh, view, dst, now)?;
        }
        Ok(())
    }

    /// Feed buffered messages into the node's machine, then finish the
    /// round once the policy is satisfied and nothing more is
    /// deliverable.  Delivery admission is the round policy's job:
    /// `Sync` holds every message until the receiver's round matches
    /// its stamp (the classic barrier — byte- and trajectory-identical
    /// to the threaded bus), `Async` hands over each per-edge FIFO
    /// head immediately, whatever round it was sent in — the machine
    /// folds in every message it has (the freshest state per edge)
    /// before its local step.
    fn pump(&mut self, sh: &Shared, view: &TopologyView, i: usize,
            now: u64) -> Result<()> {
        let li = i - self.lo;
        loop {
            if !self.exchanging[li] {
                return Ok(());
            }
            let round = self.rounds[li];
            let mut found: Option<usize> = None;
            for (src, q) in self.inboxes[li].iter() {
                if let Some(env) = q.front() {
                    match sh.policy {
                        RoundPolicy::Sync => {
                            ensure!(
                                env.round >= round,
                                "sim: node {i} holds a stale round-{} message \
                                 from {src} while in round {round}",
                                env.round
                            );
                            if env.round == round {
                                found = Some(*src as usize);
                                break;
                            }
                        }
                        RoundPolicy::Async { .. } => {
                            found = Some(*src as usize);
                            break;
                        }
                    }
                }
            }
            let Some(src) = found else {
                // Nothing (more) deliverable: step if the policy allows.
                // Under sync this fires exactly when all of this round's
                // messages are in (one per edge — the classic barrier);
                // under async also on slack within the staleness budget.
                if self.machines[li].round_complete() {
                    self.finish_round(sh, view, i, now)?;
                }
                return Ok(());
            };
            let env = {
                let inbox = &mut self.inboxes[li];
                let k = inbox
                    .binary_search_by_key(&(src as u32), |&(s, _)| s)
                    .expect("front just observed");
                inbox[k].1.pop_front().expect("front just observed")
            };
            let outv: Vec<(usize, Msg)> = {
                let mut out = Outbox::new();
                // The machine receives the SENDER's round stamp; its own
                // round only gates completion.
                self.machines[li].on_message(env.round, src, env.payload,
                                             view, self.ws.row_mut(li),
                                             &mut out)?;
                out.drain().collect()
            };
            for (to, msg) in outv {
                self.send(sh, view, i, to, round, msg, now)?;
            }
        }
    }

    fn finish_round(&mut self, sh: &Shared, view: &TopologyView, i: usize,
                    now: u64) -> Result<()> {
        let li = i - self.lo;
        let round = self.rounds[li];
        self.machines[li].round_end(round, view, self.ws.row_mut(li))?;
        self.exchanging[li] = false;
        if let Some(&epoch) = sh.sched.eval_rounds.get(&round) {
            let (acc, loss) = self.locals[li].evaluate(self.ws.row(li))?;
            let train = self.train_loss[li].take();
            self.evals.push(EvalSample {
                epoch,
                node: i,
                acc,
                loss,
                train,
                own_bytes: sh.meter.bytes_sent(i),
                t_ns: now,
            });
        }
        self.rounds[li] += 1;
        if self.rounds[li] >= sh.total_rounds {
            self.done[li] = true;
            self.finished += 1;
        } else {
            let dt = sh.compute_ns[i];
            self.queue.push(Event {
                t_ns: now.saturating_add(dt),
                key: EventKey::compute(i),
                kind: EventKind::ComputeDone { node: i },
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// The window driver
// ---------------------------------------------------------------------

/// Run one lookahead window `[*, end_ns)` on every partition — inline
/// when there is one partition (the serial fast path, no thread
/// machinery at all), fork-join otherwise.
fn run_windows(parts: &mut [Part], sh: &Shared, view: &TopologyView,
               end_ns: u64) -> Result<u64> {
    if parts.len() == 1 {
        return parts[0].run_window(sh, view, end_ns);
    }
    let results: Vec<Result<u64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = parts
            .iter_mut()
            .map(|p| scope.spawn(move || p.run_window(sh, view, end_ns)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sim worker thread panicked"))
            .collect()
    });
    let mut total = 0u64;
    for r in results {
        total += r?;
    }
    Ok(total)
}

/// Route cross-partition deliveries accumulated during the last window
/// (or churn application) into their target queues.  Runs at the
/// barrier, single-threaded; with one partition `mail` is always empty.
fn exchange_mail(parts: &mut [Part], sh: &Shared) {
    let mut moved: Vec<Event> = Vec::new();
    for p in parts.iter_mut() {
        moved.append(&mut p.mail);
    }
    for ev in moved {
        let dst = match &ev.kind {
            EventKind::Deliver { env } => env.dst,
            EventKind::ComputeDone { node } => *node,
        };
        parts[block_owner(sh.starts, dst)].queue.push(ev);
    }
}

/// Apply the churn schedule's edge liveness at `now`: kill edges that
/// churned down (purging their buffered frames as typed drops), revive
/// edges that came back (fresh incarnation, activating at `1 +
/// max(endpoint rounds)` so both endpoints open it at the same round
/// number), then notify every affected machine and re-poll its gate —
/// a node that was waiting on a now-dead edge completes its round here
/// instead of deadlocking.  Runs at window boundaries with every
/// partition quiescent, *before* any protocol event carrying the same
/// timestamp (the documented boundary order).
fn apply_churn(parts: &mut [Part], sh: &Shared, view: &mut TopologyView,
               now: u64) -> Result<()> {
    let mut affected: BTreeSet<usize> = BTreeSet::new();
    for (e, &(i, j)) in sh.graph.edges().iter().enumerate() {
        let down = sh.churn.churned_down(e, i, j, now);
        let life = view.edge_life(e);
        if life.live && down {
            view.kill_edge(e);
            sh.meter.record_edge_churn();
            // Purge frames already delivered into inbox buffers:
            // in-flight state of a dead edge drains as drops.
            for (a, b) in [(i, j), (j, i)] {
                let pb = &mut parts[block_owner(sh.starts, b)];
                let lb = b - pb.lo;
                if let Ok(k) = pb.inboxes[lb]
                    .binary_search_by_key(&(a as u32), |&(s, _)| s)
                {
                    for env in pb.inboxes[lb][k].1.drain(..) {
                        sh.meter.record_churn_drop(
                            env.payload.wire_bytes() as u64,
                        );
                    }
                }
            }
            affected.insert(i);
            affected.insert(j);
        } else if !life.live && !down {
            let round_of = |x: usize| {
                let p = &parts[block_owner(sh.starts, x)];
                p.rounds[x - p.lo]
            };
            let activation = 1 + round_of(i).max(round_of(j));
            view.revive_edge(e, activation);
            sh.meter.record_edge_churn();
            affected.insert(i);
            affected.insert(j);
        }
    }
    for &i in &affected {
        let p = &mut parts[block_owner(sh.starts, i)];
        let li = i - p.lo;
        let outv: Vec<(usize, Msg)> = {
            let mut out = Outbox::new();
            p.machines[li].on_topology(view, p.ws.row_mut(li), &mut out)?;
            out.drain().collect()
        };
        let round = p.rounds[li];
        for (to, msg) in outv {
            p.send(sh, view, i, to, round, msg, now)?;
        }
        if p.exchanging[li] {
            p.pump(sh, view, i, now)?;
        }
    }
    Ok(())
}

/// Run `sched.total_rounds()` rounds of the given per-node protocols in
/// virtual time under the given round policy (which must match the
/// policy the machines were built with).  Returns the aggregated
/// history, final parameters, and the byte/retransmit/virtual-time
/// meter.
pub fn simulate(
    graph: &Graph,
    cfg: &SimConfig,
    seed: u64,
    sched: &Schedule,
    nodes: Vec<NodeSetup>,
    policy: RoundPolicy,
    verbose: bool,
) -> Result<SimOutcome> {
    let n = graph.n();
    ensure!(n > 0, "sim: empty graph");
    ensure!(
        nodes.len() == n,
        "sim: {} node setups for a {n}-node graph",
        nodes.len()
    );
    cfg.link.validate()?;
    let mut seen_edges: BTreeSet<usize> = BTreeSet::new();
    for (edge, spec) in &cfg.edge_links {
        ensure!(
            *edge < graph.edges().len(),
            "sim: per-edge link for edge {edge}, but the graph has only \
             {} edges",
            graph.edges().len()
        );
        spec.validate()?;
        ensure!(
            seen_edges.insert(*edge),
            "sim: duplicate per-edge link override for edge {edge}"
        );
    }
    // The engine's delivery policy and each machine's gating policy
    // must agree — a mismatch would surface later as confusing
    // admission errors (or silently mislabel a run).
    for (i, s) in nodes.iter().enumerate() {
        if let Some(p) = s.machine.policy() {
            ensure!(
                p == policy,
                "sim: node {i} was built for `{}` rounds but the engine \
                 is driving `{}`",
                p.name(),
                policy.name()
            );
        }
    }
    // Churn-schedule validation: explicit windows must reference real
    // edges/nodes (typed startup errors, not mid-run index panics).
    if let Some(e) = cfg.churn.max_edge_index() {
        ensure!(
            e < graph.edges().len(),
            "sim: churn window for edge {e}, but the graph has only {} \
             edges",
            graph.edges().len()
        );
    }
    if let Some(node) = cfg.churn.max_node_index() {
        ensure!(node < n, "sim: churn event for node {node} out of range");
    }
    let total_rounds = sched.total_rounds();
    let meter = Meter::with_edges(n, graph.edges().len());
    if total_rounds == 0 {
        let w = nodes.into_iter().map(|s| s.w).collect();
        return Ok(SimOutcome {
            history: History::default(),
            vtime_ns: 0,
            meter,
            w,
            max_staleness: 0,
            edges_churned: 0,
        });
    }

    let d = nodes.iter().map(|s| s.w.len()).max().unwrap_or(0);
    let mut compute_ns =
        vec![cfg.compute_ns_per_step.saturating_mul(sched.local_steps as u64); n];
    let mut straggler_seen = BTreeSet::new();
    for &(i, f) in &cfg.stragglers {
        ensure!(i < n, "sim: straggler index {i} out of range");
        ensure!(f > 0.0, "sim: straggler factor must be positive");
        // Like edge_links: a repeated entry would silently compound
        // factors multiplicatively, which is never what it means.
        ensure!(
            straggler_seen.insert(i),
            "sim: duplicate straggler entry for node {i}"
        );
        compute_ns[i] = (compute_ns[i] as f64 * f) as u64;
    }

    // Partitioning and conservative lookahead.  With one partition the
    // lookahead is unbounded (windows split only at churn boundaries)
    // and the loop below IS the serial engine; with P > 1 a window may
    // extend `lookahead` past its first event, because no
    // cross-partition message can arrive sooner than `send time + min
    // cross-edge latency`.
    let mut nparts = cfg.threads.max(1).min(n);
    let mut starts = partition_blocks(n, nparts);
    let mut lookahead = u64::MAX;
    if nparts > 1 {
        let mut la = u64::MAX;
        for (e, &(i, j)) in graph.edges().iter().enumerate() {
            if block_owner(&starts, i) != block_owner(&starts, j) {
                let spec = cfg
                    .edge_links
                    .iter()
                    .find(|(k, _)| *k == e)
                    .map(|(_, s)| s)
                    .unwrap_or(&cfg.link);
                la = la.min(spec.min_latency_ns());
            }
        }
        if la == 0 {
            // Zero-latency cross-partition links give the conservative
            // engine no window to run ahead in — serial is the only
            // correct schedule.  Fall back (results are identical by
            // construction, only wall-clock differs).
            if verbose {
                println!(
                    "[sim] threads {} requested but a cross-partition \
                     link has zero latency; running serial",
                    cfg.threads
                );
            }
            nparts = 1;
            starts = partition_blocks(n, 1);
        } else {
            lookahead = la;
        }
    }

    let csr = Csr::build(graph);
    // Calendar-queue day width: a fraction of the round pace, so one
    // round's events spread over a few days.
    let pace = cfg
        .compute_ns_per_step
        .saturating_mul(sched.local_steps as u64)
        .max(8);
    let width = (pace / 8).max(1);

    let mut parts: Vec<Part> = Vec::with_capacity(nparts);
    let mut setups = nodes.into_iter();
    for p in 0..nparts {
        let (lo, hi) = (starts[p], starts[p + 1]);
        let count = hi - lo;
        let mut machines = Vec::with_capacity(count);
        let mut locals = Vec::with_capacity(count);
        let mut ws = Vec::with_capacity(count);
        for s in setups.by_ref().take(count) {
            machines.push(s.machine);
            locals.push(s.local);
            ws.push(s.w);
        }
        let inboxes = (lo..hi)
            .map(|i| {
                graph
                    .neighbors(i)
                    .iter()
                    .map(|&j| (j as u32, VecDeque::new()))
                    .collect()
            })
            .collect();
        parts.push(Part {
            lo,
            hi,
            machines,
            locals,
            // Bit-exact packing: the arena stores the same values at
            // the same logical indices the Vec-of-Vecs did.
            ws: Arena::from_vecs(ws),
            rounds: vec![0; count],
            exchanging: vec![false; count],
            done: vec![false; count],
            train_loss: (0..count).map(|_| Mean::default()).collect(),
            inboxes,
            out: vec![OutLink::default(); csr.off[hi] - csr.off[lo]],
            out_base: csr.off[lo],
            link: cfg.link.build(),
            edge_links: cfg
                .edge_links
                .iter()
                .map(|(e, s)| (*e, s.build()))
                .collect(),
            queue: CalendarQueue::new(width),
            mail: Vec::new(),
            finished: 0,
            last_t: 0,
            evals: Vec::new(),
        });
    }

    let zeros = vec![0.0f32; d];
    let sh = Shared {
        graph,
        csr: &csr,
        sched,
        churn: &cfg.churn,
        meter: &meter,
        policy,
        compute_ns: &compute_ns,
        zeros: &zeros,
        starts: &starts,
        seed,
        n,
        total_rounds,
        verbose,
    };
    let mut view = TopologyView::full(graph.edges().len());

    // Apply the schedule's t = 0 state (edges absent from the start,
    // nodes that join later) before anyone computes, then arm the first
    // transition boundary.
    let mut armed: Option<u64> = None;
    if cfg.churn.has_churn() {
        apply_churn(&mut parts, &sh, &mut view, 0)?;
        exchange_mail(&mut parts, &sh);
        armed = cfg.churn.next_transition_after(0);
    }

    // Every node starts its round-0 local compute at t = 0.
    for (p, part) in parts.iter_mut().enumerate() {
        for i in starts[p]..starts[p + 1] {
            part.queue.push(Event {
                t_ns: compute_ns[i],
                key: EventKey::compute(i),
                kind: EventKind::ComputeDone { node: i },
            });
        }
    }

    // The window loop.  Guard against a churn-only spin: the random
    // rule schedules slot boundaries forever, so if nothing but churn
    // boundaries fire for a very long stretch the run is deadlocked —
    // report it instead of looping silently.
    let mut churn_streak = 0u64;
    let mut final_t = 0u64;
    loop {
        let head = parts.iter_mut().filter_map(|p| p.queue.peek_t()).min();
        let boundary = match (head, armed) {
            (None, None) => break,
            (None, Some(tc)) => Some(tc),
            (Some(t), Some(tc)) if tc <= t => Some(tc),
            (Some(_), _) => None,
        };
        if let Some(tc) = boundary {
            churn_streak += 1;
            ensure!(
                churn_streak < 200_000,
                "sim deadlock: {churn_streak} consecutive churn \
                 events with no protocol progress"
            );
            apply_churn(&mut parts, &sh, &mut view, tc)?;
            exchange_mail(&mut parts, &sh);
            final_t = final_t.max(tc);
            // Keep the boundary clock armed while work remains.
            let finished: usize = parts.iter().map(|p| p.finished).sum();
            armed = if finished < n {
                cfg.churn.next_transition_after(tc)
            } else {
                None
            };
            continue;
        }
        let t = head.expect("non-boundary iteration has a head event");
        let end = armed
            .unwrap_or(u64::MAX)
            .min(t.saturating_add(lookahead));
        let processed = run_windows(&mut parts, &sh, &view, end)?;
        if processed > 0 {
            churn_streak = 0;
        }
        exchange_mail(&mut parts, &sh);
    }

    let finished: usize = parts.iter().map(|p| p.finished).sum();
    let stuck: Vec<(usize, usize, bool)> = parts
        .iter()
        .flat_map(|p| {
            (p.lo..p.hi).filter_map(move |i| {
                let li = i - p.lo;
                (!p.done[li]).then_some((i, p.rounds[li], p.exchanging[li]))
            })
        })
        .take(8)
        .collect();
    ensure!(
        finished == n,
        "sim deadlock: {}/{} nodes finished; stuck (node, round, \
         exchanging): {:?}",
        finished,
        n,
        stuck
    );
    final_t =
        final_t.max(parts.iter().map(|p| p.last_t).max().unwrap_or(0));
    meter.advance_vtime_ns(final_t);

    // Fold per-node eval samples into per-epoch records.  Samples sort
    // by (epoch, node) — a total order independent of partitioning —
    // and means fold in node order, exactly as the heap-era engine's
    // slot fill did.
    let mut samples: Vec<EvalSample> = Vec::new();
    for p in parts.iter_mut() {
        samples.append(&mut p.evals);
    }
    samples.sort_by_key(|s| (s.epoch, s.node));
    let mut history = History::default();
    let mut idx = 0usize;
    while idx < samples.len() {
        let epoch = samples[idx].epoch;
        let mut j = idx;
        while j < samples.len() && samples[j].epoch == epoch {
            j += 1;
        }
        let group = &samples[idx..j];
        for w in group.windows(2) {
            ensure!(
                w[0].node != w[1].node,
                "node {} evaluated epoch {epoch} twice",
                w[0].node
            );
        }
        if group.len() == n {
            let (mut a, mut l, mut t, mut b) = (
                Mean::default(),
                Mean::default(),
                Mean::default(),
                Mean::default(),
            );
            let mut t_max = 0u64;
            for s in group {
                a.add(s.acc);
                l.add(s.loss);
                t.add(s.train);
                b.add(s.own_bytes as f64);
                t_max = t_max.max(s.t_ns);
            }
            let rec = EpochRecord {
                epoch,
                mean_accuracy: a.take(),
                mean_loss: l.take(),
                train_loss: t.take(),
                cum_bytes_per_node: b.take(),
                sim_time_secs: t_max as f64 / 1e9,
            };
            if verbose {
                println!(
                    "[sim] epoch {:>4}: acc {:.3} loss {:.3} train {:.3} \
                     sent/node {:.0} KB  t={:.3}s",
                    rec.epoch,
                    rec.mean_accuracy,
                    rec.mean_loss,
                    rec.train_loss,
                    rec.cum_bytes_per_node / 1024.0,
                    rec.sim_time_secs
                );
            }
            history.push(rec);
        }
        idx = j;
    }

    let max_staleness = parts
        .iter()
        .flat_map(|p| p.machines.iter())
        .map(|m| m.max_staleness_seen())
        .max()
        .unwrap_or(0);
    let mut w: Vec<Vec<f32>> = Vec::with_capacity(n);
    for p in parts {
        w.extend(p.ws.into_vecs());
    }
    let edges_churned = meter.edges_churned();
    Ok(SimOutcome {
        history,
        vtime_ns: meter.vtime_ns(),
        meter,
        w,
        max_staleness,
        edges_churned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{build_machine, AlgorithmSpec, BuildCtx, DualPath};
    use crate::model::DatasetManifest;

    fn machine_setup(
        graph: &Arc<Graph>,
        alg: &AlgorithmSpec,
        seed: u64,
        rounds_per_epoch: usize,
    ) -> Vec<NodeSetup> {
        machine_setup_policy(graph, alg, seed, rounds_per_epoch,
                             RoundPolicy::Sync)
    }

    fn machine_setup_policy(
        graph: &Arc<Graph>,
        alg: &AlgorithmSpec,
        seed: u64,
        rounds_per_epoch: usize,
        round_policy: RoundPolicy,
    ) -> Vec<NodeSetup> {
        let ds = DatasetManifest::synthetic_linear("t", (2, 2, 1), 3, 2, 2);
        (0..graph.n())
            .map(|node| {
                let ctx = BuildCtx {
                    node,
                    graph: Arc::clone(graph),
                    manifest: ds.clone(),
                    seed,
                    eta: 0.05,
                    local_steps: 1,
                    rounds_per_epoch,
                    dual_path: DualPath::Native,
                    runtime: None,
                    round_policy,
                };
                let mut rng = Pcg::new(900 + node as u64);
                let w = (0..ds.d_pad).map(|_| rng.normal_f32()).collect();
                NodeSetup {
                    machine: build_machine(alg, &ctx).unwrap(),
                    local: Box::new(NullLocal),
                    w,
                }
            })
            .collect()
    }

    #[test]
    fn schedule_eval_rounds() {
        let s = Schedule::new(7, 4, 5, 3);
        assert_eq!(s.total_rounds(), 28);
        // Epochs 3, 6, 7 evaluate, at the last round of each.
        let expect: BTreeMap<usize, usize> =
            [(11, 3), (23, 6), (27, 7)].into_iter().collect();
        assert_eq!(s.eval_rounds, expect);
        assert_eq!(s.local_steps, 5);
    }

    #[test]
    fn two_node_exchange_virtual_clock() {
        // chain(2), ECL dense, 1 round: local compute takes 1000 ns,
        // the constant link 1 us, so the run ends at exactly 2000 ns.
        let graph = Arc::new(Graph::chain(2));
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 1_000,
            ..SimConfig::default()
        };
        let sched = Schedule::new(1, 1, 1, 1);
        let alg = AlgorithmSpec::Ecl { theta: 1.0 };
        let nodes = machine_setup(&graph, &alg, 7, 1);
        let out = simulate(&graph, &cfg, 7, &sched, nodes, RoundPolicy::Sync,
                           false).unwrap();
        // sends fire at t=1000, arrive at t=2000.
        assert_eq!(out.vtime_ns, 2_000);
        // ECL dense: d floats both ways.
        let d = DatasetManifest::synthetic_linear("t", (2, 2, 1), 3, 2, 2).d;
        assert_eq!(out.meter.total_bytes() as usize, 2 * 4 * d);
        assert_eq!(out.meter.total_retransmit_bytes(), 0);
    }

    #[test]
    fn straggler_stretches_virtual_time() {
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(2, 2, 1, 1);
        let alg = AlgorithmSpec::DPsgd;
        let base_cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 100_000,
            ..SimConfig::default()
        };
        let slow_cfg = SimConfig {
            stragglers: vec![(2, 8.0)],
            ..base_cfg.clone()
        };
        let fast = simulate(&graph, &base_cfg, 3, &sched,
                            machine_setup(&graph, &alg, 3, 2),
                            RoundPolicy::Sync, false)
            .unwrap();
        let slow = simulate(&graph, &slow_cfg, 3, &sched,
                            machine_setup(&graph, &alg, 3, 2),
                            RoundPolicy::Sync, false)
            .unwrap();
        assert!(slow.vtime_ns > fast.vtime_ns * 4,
                "straggler {} vs {}", slow.vtime_ns, fast.vtime_ns);
        // Same traffic either way.
        assert_eq!(slow.meter.total_bytes(), fast.meter.total_bytes());
        // Repeated straggler entries would compound silently — rejected.
        let dup_cfg = SimConfig {
            stragglers: vec![(2, 2.0), (2, 2.0)],
            ..base_cfg
        };
        let err = simulate(&graph, &dup_cfg, 3, &sched,
                           machine_setup(&graph, &alg, 3, 2),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("duplicate straggler"), "{err}");
    }

    #[test]
    fn outage_holds_messages_until_edge_recovers() {
        let graph = Arc::new(Graph::chain(2));
        let sched = Schedule::new(1, 1, 1, 1);
        let alg = AlgorithmSpec::Ecl { theta: 1.0 };
        let mut churn = ChurnSchedule::default();
        // Edge 0 in OUTAGE from t=0 until t=5 ms: round-0 sends (at
        // ~1 us) stall until the window ends — held, never dropped,
        // with zero topology transitions (state-preserving semantics).
        churn.add_outage(0, 0, 5_000_000);
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 1_000,
            churn,
            ..SimConfig::default()
        };
        let out = simulate(&graph, &cfg, 11, &sched,
                           machine_setup(&graph, &alg, 11, 1),
                           RoundPolicy::Sync, false)
            .unwrap();
        assert!(out.vtime_ns >= 5_000_000, "vtime {}", out.vtime_ns);
        assert_eq!(out.edges_churned, 0, "outage is not churn");
        assert_eq!(out.meter.churn_dropped_frames(), 0);
        let no_outage = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 1_000,
            ..SimConfig::default()
        };
        let base = simulate(&graph, &no_outage, 11, &sched,
                            machine_setup(&graph, &alg, 11, 1),
                            RoundPolicy::Sync, false)
            .unwrap();
        assert!(base.vtime_ns < out.vtime_ns);
    }

    #[test]
    fn churn_removes_edge_drops_in_flight_and_revives_fresh() {
        // ring(3), C-ECL sync.  Edge 0 = (0, 1) churns out over rounds
        // 1..2 and comes back: the run completes, the in-flight frames
        // of the removal window drain as typed drops (byte-exact: sends
        // stay metered), and the lifecycle counter sees both the kill
        // and the revival.
        let graph = Arc::new(Graph::ring(3));
        let sched = Schedule::new(6, 1, 1, 6);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.5,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let mut churn = ChurnSchedule::default();
        // Compute = 100 us/round, latency 10 us: round-0 frames are in
        // flight during (100, 110) us, so a kill at 105 us catches them
        // mid-air — they MUST drain as typed drops, and the churn event
        // must unblock the endpoints that were waiting on them.
        churn.add_edge_down(0, 105_000, 350_000);
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 10 },
            compute_ns_per_step: 100_000,
            churn,
            ..SimConfig::default()
        };
        let out = simulate(&graph, &cfg, 5, &sched,
                           machine_setup(&graph, &alg, 5, 1),
                           RoundPolicy::Sync, false)
            .unwrap();
        assert_eq!(out.edges_churned, 2, "one kill + one revival");
        assert!(out.meter.churn_dropped_frames() > 0,
                "in-flight frames must drain as drops");
        assert!(out.meter.churn_dropped_bytes() > 0);
        // Replay determinism with churn in the schedule.
        let out2 = simulate(&graph, &cfg, 5, &sched,
                            machine_setup(&graph, &alg, 5, 1),
                            RoundPolicy::Sync, false)
            .unwrap();
        assert_eq!(out.meter.total_bytes(), out2.meter.total_bytes());
        assert_eq!(out.meter.churn_dropped_frames(),
                   out2.meter.churn_dropped_frames());
        assert_eq!(out.w, out2.w, "churn replay must be bit-identical");
    }

    #[test]
    fn node_leave_and_join_complete_without_panics() {
        // Node 2 leaves a ring(4) mid-run (all its edges churn out);
        // node 3 joins late (absent from t=0).  Both engines' gates
        // skip dead edges, so every node still finishes its rounds.
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(6, 1, 1, 6);
        let alg = AlgorithmSpec::DPsgd;
        let mut churn = ChurnSchedule::default();
        churn.add_node_leave(2, 400_000);
        churn.add_node_join(3, 250_000);
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 10 },
            compute_ns_per_step: 100_000,
            churn,
            ..SimConfig::default()
        };
        let out = simulate(&graph, &cfg, 9, &sched,
                           machine_setup(&graph, &alg, 9, 1),
                           RoundPolicy::Sync, false)
            .unwrap();
        assert!(out.edges_churned >= 4, "join + leave must transition");
        assert_eq!(out.history.records.len(), 1, "final epoch still evals");
        // Bad schedules are typed startup errors.
        let mut bad = ChurnSchedule::default();
        bad.add_edge_down(99, 0, 10);
        let cfg_bad = SimConfig {
            churn: bad,
            ..SimConfig::default()
        };
        let err = simulate(&graph, &cfg_bad, 9, &sched,
                           machine_setup(&graph, &alg, 9, 1),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("edge 99"), "{err}");
        let mut bad = ChurnSchedule::default();
        bad.add_node_leave(7, 10);
        let cfg_bad = SimConfig {
            churn: bad,
            ..SimConfig::default()
        };
        let err = simulate(&graph, &cfg_bad, 9, &sched,
                           machine_setup(&graph, &alg, 9, 1),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("node 7"), "{err}");
    }

    #[test]
    fn replay_is_bit_identical() {
        let graph = Arc::new(Graph::ring(5));
        let sched = Schedule::new(2, 3, 2, 1);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.4,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let cfg = SimConfig {
            link: LinkSpec::Lossy {
                latency_us: 50,
                mbit_per_sec: 100.0,
                drop_p: 0.3,
            },
            ..SimConfig::default()
        };
        let a = simulate(&graph, &cfg, 21, &sched,
                         machine_setup(&graph, &alg, 21, 3),
                         RoundPolicy::Sync, false)
            .unwrap();
        let b = simulate(&graph, &cfg, 21, &sched,
                         machine_setup(&graph, &alg, 21, 3),
                         RoundPolicy::Sync, false)
            .unwrap();
        assert_eq!(a.vtime_ns, b.vtime_ns);
        assert_eq!(a.meter.total_bytes(), b.meter.total_bytes());
        assert_eq!(
            a.meter.total_retransmit_bytes(),
            b.meter.total_retransmit_bytes()
        );
        assert_eq!(a.w, b.w, "final parameters must replay bit-identically");
        assert!(a.meter.total_retransmit_bytes() > 0, "p=0.3 must retransmit");
    }

    #[test]
    fn parallel_partitions_match_serial_bit_for_bit() {
        // The conservative-PDES contract in miniature: ring(6) over a
        // lossy latency link, three partitions vs one — identical
        // virtual clock, byte counters, retransmits, and parameters.
        let graph = Arc::new(Graph::ring(6));
        let sched = Schedule::new(2, 2, 1, 1);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.4,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let cfg = SimConfig {
            link: LinkSpec::Lossy {
                latency_us: 50,
                mbit_per_sec: 100.0,
                drop_p: 0.3,
            },
            stragglers: vec![(1, 3.0)],
            ..SimConfig::default()
        };
        let par_cfg = SimConfig { threads: 3, ..cfg.clone() };
        let serial = simulate(&graph, &cfg, 21, &sched,
                              machine_setup(&graph, &alg, 21, 2),
                              RoundPolicy::Sync, false)
            .unwrap();
        let par = simulate(&graph, &par_cfg, 21, &sched,
                           machine_setup(&graph, &alg, 21, 2),
                           RoundPolicy::Sync, false)
            .unwrap();
        assert_eq!(serial.vtime_ns, par.vtime_ns);
        assert_eq!(serial.meter.total_bytes(), par.meter.total_bytes());
        assert_eq!(
            serial.meter.total_retransmit_bytes(),
            par.meter.total_retransmit_bytes()
        );
        assert_eq!(
            serial.meter.edge_payload_bytes(),
            par.meter.edge_payload_bytes()
        );
        assert_eq!(serial.w, par.w, "parallel must replay serial exactly");
        assert_eq!(
            serial.history.records.len(),
            par.history.records.len()
        );
        for (a, b) in serial
            .history
            .records
            .iter()
            .zip(par.history.records.iter())
        {
            assert_eq!(a.epoch, b.epoch);
            assert_eq!(a.mean_accuracy.to_bits(), b.mean_accuracy.to_bits());
            assert_eq!(a.sim_time_secs.to_bits(), b.sim_time_secs.to_bits());
            assert_eq!(
                a.cum_bytes_per_node.to_bits(),
                b.cum_bytes_per_node.to_bits()
            );
        }
    }

    #[test]
    fn parallel_with_ideal_cross_links_falls_back_to_serial() {
        // Zero-latency cross-partition links leave no conservative
        // lookahead; the engine must fall back to one partition and
        // still produce the serial result.
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(1, 2, 1, 1);
        let alg = AlgorithmSpec::DPsgd;
        let serial = simulate(&graph, &SimConfig::default(), 3, &sched,
                              machine_setup(&graph, &alg, 3, 2),
                              RoundPolicy::Sync, false)
            .unwrap();
        let par_cfg = SimConfig { threads: 4, ..SimConfig::default() };
        let par = simulate(&graph, &par_cfg, 3, &sched,
                           machine_setup(&graph, &alg, 3, 2),
                           RoundPolicy::Sync, false)
            .unwrap();
        assert_eq!(serial.vtime_ns, par.vtime_ns);
        assert_eq!(serial.w, par.w);
    }

    #[test]
    fn per_edge_link_override_slows_only_its_edge() {
        // chain(3): edges 0 = (0,1), 1 = (1,2).  Overriding edge 1 with
        // a high-latency link must stretch virtual time; overriding a
        // third, nonexistent edge must be rejected.
        let graph = Arc::new(Graph::chain(3));
        let sched = Schedule::new(1, 1, 1, 1);
        let alg = AlgorithmSpec::Ecl { theta: 1.0 };
        let base = SimConfig {
            link: LinkSpec::Constant { latency_us: 1 },
            compute_ns_per_step: 1_000,
            ..SimConfig::default()
        };
        let hetero = SimConfig {
            edge_links: vec![(1, LinkSpec::Constant { latency_us: 4_000 })],
            ..base.clone()
        };
        let fast = simulate(&graph, &base, 5, &sched,
                            machine_setup(&graph, &alg, 5, 1),
                            RoundPolicy::Sync, false)
            .unwrap();
        let slow = simulate(&graph, &hetero, 5, &sched,
                            machine_setup(&graph, &alg, 5, 1),
                            RoundPolicy::Sync, false)
            .unwrap();
        // Same payload traffic, different clock: only edge 1 slowed.
        assert_eq!(fast.meter.total_bytes(), slow.meter.total_bytes());
        assert_eq!(fast.vtime_ns, 1_000 + 1_000);
        assert_eq!(slow.vtime_ns, 1_000 + 4_000_000);

        let bad = SimConfig {
            edge_links: vec![(7, LinkSpec::Ideal)],
            ..base.clone()
        };
        let err = simulate(&graph, &bad, 5, &sched,
                           machine_setup(&graph, &alg, 5, 1),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("edge 7"), "{err}");
        let dup = SimConfig {
            edge_links: vec![(0, LinkSpec::Ideal), (0, LinkSpec::Ideal)],
            ..base
        };
        let err = simulate(&graph, &dup, 5, &sched,
                           machine_setup(&graph, &alg, 5, 1),
                           RoundPolicy::Sync, false)
            .err()
            .unwrap();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn async_rounds_hide_a_slow_edge_within_staleness() {
        // ring(4) with one 10x-latency edge.  Sync: the whole lockstep
        // ring is throttled through that edge every round.  Async:2 the
        // slow edge lags up to two rounds and everyone else free-runs —
        // strictly less virtual time for the same number of rounds, and
        // the staleness bound is both observed and reached.
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(4, 2, 1, 4);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.4,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let cfg = SimConfig {
            link: LinkSpec::Constant { latency_us: 10 },
            edge_links: vec![(0, LinkSpec::Constant { latency_us: 150 })],
            compute_ns_per_step: 100_000,
            ..SimConfig::default()
        };
        let sync = simulate(&graph, &cfg, 3, &sched,
                            machine_setup(&graph, &alg, 3, 2),
                            RoundPolicy::Sync, false)
            .unwrap();
        let policy = RoundPolicy::Async { max_staleness: 2 };
        let async_out = simulate(
            &graph,
            &cfg,
            3,
            &sched,
            machine_setup_policy(&graph, &alg, 3, 2, policy),
            policy,
            false,
        )
        .unwrap();
        assert_eq!(sync.max_staleness, 0, "sync must never lag");
        assert!(async_out.max_staleness >= 1, "slow edge must actually lag");
        assert!(async_out.max_staleness <= 2, "staleness bound violated");
        // Identical payload traffic (every node still sends every
        // round), strictly less virtual time.
        assert_eq!(sync.meter.total_bytes(), async_out.meter.total_bytes());
        assert!(
            async_out.vtime_ns < sync.vtime_ns,
            "async {} !< sync {}",
            async_out.vtime_ns,
            sync.vtime_ns
        );
    }

    #[test]
    fn engine_rejects_policy_mismatch_with_machines() {
        // Machines built for Sync cannot be driven under Async (and
        // vice versa) — a typed startup error, not a mid-run puzzle.
        let graph = Arc::new(Graph::ring(4));
        let sched = Schedule::new(1, 1, 1, 1);
        let alg = AlgorithmSpec::DPsgd;
        let err = simulate(
            &graph,
            &SimConfig::default(),
            3,
            &sched,
            machine_setup(&graph, &alg, 3, 1), // built for Sync
            RoundPolicy::Async { max_staleness: 1 },
            false,
        )
        .err()
        .unwrap();
        assert!(err.to_string().contains("built for `sync`"), "{err}");
    }

    #[test]
    fn async_replay_is_bit_identical() {
        let graph = Arc::new(Graph::ring(5));
        let sched = Schedule::new(2, 3, 2, 1);
        let alg = AlgorithmSpec::CEcl {
            k_frac: 0.4,
            theta: 1.0,
            dense_first_epoch: false,
        };
        let cfg = SimConfig {
            link: LinkSpec::Lossy {
                latency_us: 50,
                mbit_per_sec: 100.0,
                drop_p: 0.3,
            },
            stragglers: vec![(2, 4.0)],
            ..SimConfig::default()
        };
        let policy = RoundPolicy::Async { max_staleness: 3 };
        let run = || {
            simulate(&graph, &cfg, 21, &sched,
                     machine_setup_policy(&graph, &alg, 21, 3, policy),
                     policy, false)
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.vtime_ns, b.vtime_ns);
        assert_eq!(a.meter.total_bytes(), b.meter.total_bytes());
        assert_eq!(a.w, b.w, "async replay must be bit-identical");
        assert_eq!(a.max_staleness, b.max_staleness);
        assert!(a.max_staleness <= 3, "bound violated: {}", a.max_staleness);
    }
}
