//! Event scheduling for the virtual-time engine: the explicit event
//! total order and a calendar-queue priority structure.
//!
//! ## The event total order (the determinism contract)
//!
//! Pop order is `(t_ns, key)` where [`EventKey`] is a **structural**
//! sequence number derived from the event's content, not from a global
//! push counter:
//!
//! * class `0` — `ComputeDone`, keyed by node id;
//! * class `1` — `Deliver`, keyed by `(src, dst, fifo)` with `fifo` the
//!   per-directed-edge send counter (monotone at the sender).
//!
//! Two properties follow.  First, equal-timestamp events have one
//! documented order: compute completions fire before same-instant
//! deliveries, node-ascending; same-instant deliveries fire in
//! `(src, dst)` order and, within one directed edge, in send (FIFO)
//! order.  Second — and this is why the key is structural rather than a
//! push-order counter — any scheduler that respects `(t_ns, key)`
//! produces the same pop sequence from the same event *set*, regardless
//! of push order or of which partition pushed the event.  The binary
//! heap and the calendar queue agree by construction (pinned by the
//! regression tests below), and the parallel conservative engine's
//! per-partition queues replay the serial engine's per-node event order
//! exactly.
//!
//! ## The calendar queue
//!
//! [`CalendarQueue`] is a classic calendar queue (Brown 1988): a wheel
//! of `nbuckets` days of `width_ns` virtual nanoseconds each.  An event
//! for day `d = t_ns / width_ns` lands in bucket `d % nbuckets` if it
//! is within one wheel revolution of the current day, in the sorted
//! `overflow` heap otherwise.  The current day's events are drained
//! into a small binary heap (`today`), so insert and pop are O(1)
//! amortized at high event rates while degenerate workloads (every
//! event at one timestamp) merely degrade to binary-heap behaviour.
//! The wheel grows (rebuild, power of two) when a day's population
//! makes bucket scans dominate.

use std::cmp::Ordering as CmpOrdering;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::comm::Envelope;

/// What fires when an event's virtual time arrives.
#[derive(Debug)]
pub(crate) enum EventKind {
    /// A node finished its K local steps and enters its exchange phase.
    ComputeDone { node: usize },
    /// A message reaches its destination.
    Deliver { env: Envelope },
}

/// Structural tie-break key — see the module docs for the total order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) struct EventKey {
    /// 0 = ComputeDone, 1 = Deliver.
    pub class: u8,
    /// ComputeDone: node.  Deliver: src.
    pub a: u32,
    /// Deliver: dst.
    pub b: u32,
    /// Deliver: per-directed-edge send counter.
    pub fifo: u64,
}

impl EventKey {
    pub fn compute(node: usize) -> EventKey {
        EventKey { class: 0, a: node as u32, b: 0, fifo: 0 }
    }

    pub fn deliver(src: usize, dst: usize, fifo: u64) -> EventKey {
        EventKey { class: 1, a: src as u32, b: dst as u32, fifo }
    }
}

#[derive(Debug)]
pub(crate) struct Event {
    pub t_ns: u64,
    pub key: EventKey,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t_ns == other.t_ns && self.key == other.key
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.t_ns
            .cmp(&other.t_ns)
            .then_with(|| self.key.cmp(&other.key))
    }
}

const INITIAL_BUCKETS: usize = 64;
const MAX_BUCKETS: usize = 1 << 20;
/// Grow the wheel when it holds more than this many events per bucket.
const GROW_AT: usize = 4;

/// Calendar-queue event scheduler.  `pop` respects the `(t_ns, key)`
/// total order exactly (pinned against [`HeapQueue`] in tests).
///
/// Invariant: events are never scheduled in the past — `push(t)` with
/// `t` at or before the last popped timestamp is still *correct* (it
/// routes to `today`), but the engine never does it.
pub(crate) struct CalendarQueue {
    /// Current-day events, heapified for in-day total order.
    today: BinaryHeap<Reverse<Event>>,
    /// One revolution of days; bucket `d % nbuckets` holds day `d`.
    wheel: Vec<Vec<Event>>,
    /// Events at least one revolution in the future.
    overflow: BinaryHeap<Reverse<Event>>,
    /// Virtual nanoseconds per day.
    width: u64,
    /// Day currently being drained (`today` holds its events).
    day: u64,
    /// Events resident in the wheel (excludes `today` and `overflow`).
    wheel_len: usize,
    len: usize,
}

impl CalendarQueue {
    /// `width_hint_ns` sets the day width — roughly the expected
    /// inter-event timescale; any positive value is correct.
    pub fn new(width_hint_ns: u64) -> CalendarQueue {
        CalendarQueue {
            today: BinaryHeap::new(),
            wheel: (0..INITIAL_BUCKETS).map(|_| Vec::new()).collect(),
            overflow: BinaryHeap::new(),
            width: width_hint_ns.max(1),
            day: 0,
            wheel_len: 0,
            len: 0,
        }
    }

    /// Total resident events.  The engine tracks emptiness through
    /// `peek_t`; only the regression tests need the count.
    #[cfg(test)]
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn push(&mut self, ev: Event) {
        self.len += 1;
        let d = ev.t_ns / self.width;
        let nb = self.wheel.len() as u64;
        if d <= self.day {
            self.today.push(Reverse(ev));
        } else if d < self.day + nb {
            self.wheel[(d % nb) as usize].push(ev);
            self.wheel_len += 1;
            if self.wheel_len > GROW_AT * self.wheel.len()
                && self.wheel.len() < MAX_BUCKETS
            {
                self.grow();
            }
        } else {
            self.overflow.push(Reverse(ev));
        }
    }

    /// Timestamp of the next event without removing it.
    pub fn peek_t(&mut self) -> Option<u64> {
        self.ensure_today();
        self.today.peek().map(|Reverse(e)| e.t_ns)
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.ensure_today();
        let ev = self.today.pop().map(|Reverse(e)| e);
        if ev.is_some() {
            self.len -= 1;
        }
        ev
    }

    /// Advance `day` until `today` holds the next event (if any).
    fn ensure_today(&mut self) {
        while self.today.is_empty() && self.len > 0 {
            let nb = self.wheel.len() as u64;
            // Next populated wheel day ahead of `day`.  A forward scan
            // can trust bucket occupancy: an event of day D sits in its
            // bucket only while D is within one revolution of the day
            // at insert time, so the first nonempty bucket the scan
            // meets holds exactly that day's events.
            let wheel_day = if self.wheel_len > 0 {
                (1..=nb)
                    .map(|k| self.day + k)
                    .find(|d| !self.wheel[(d % nb) as usize].is_empty())
            } else {
                None
            };
            let over_day =
                self.overflow.peek().map(|Reverse(e)| e.t_ns / self.width);
            let next = match (wheel_day, over_day) {
                (Some(w), Some(o)) => w.min(o),
                (Some(w), None) => w,
                (None, Some(o)) => o,
                (None, None) => unreachable!("len > 0 with no events"),
            };
            self.day = next;
            let bucket =
                std::mem::take(&mut self.wheel[(next % nb) as usize]);
            self.wheel_len -= bucket.len();
            for ev in bucket {
                self.today.push(Reverse(ev));
            }
            while let Some(Reverse(e)) = self.overflow.peek() {
                if e.t_ns / self.width != next {
                    break;
                }
                let Reverse(e) = self.overflow.pop().expect("just peeked");
                self.today.push(Reverse(e));
            }
        }
    }

    /// Double the wheel (rebuild).  Overflow events stay put — they are
    /// re-examined per revolution by `ensure_today`, which is correct
    /// if not optimal; the rebuild only redistributes wheel residents.
    fn grow(&mut self) {
        let nb = (self.wheel.len() * 2).min(MAX_BUCKETS) as u64;
        let old: Vec<Event> =
            self.wheel.iter_mut().flat_map(std::mem::take).collect();
        self.wheel = (0..nb).map(|_| Vec::new()).collect();
        self.wheel_len = 0;
        for ev in old {
            let d = ev.t_ns / self.width;
            debug_assert!(d > self.day && d < self.day + nb);
            self.wheel[(d % nb) as usize].push(ev);
            self.wheel_len += 1;
        }
    }
}

/// Reference scheduler: a plain binary min-heap over the same
/// `(t_ns, key)` order.  Exists so the calendar queue has something to
/// agree with in the regression tests.
#[cfg(test)]
pub(crate) struct HeapQueue {
    heap: BinaryHeap<Reverse<Event>>,
}

#[cfg(test)]
impl HeapQueue {
    pub fn new() -> HeapQueue {
        HeapQueue { heap: BinaryHeap::new() }
    }

    pub fn push(&mut self, ev: Event) {
        self.heap.push(Reverse(ev));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Msg;

    fn compute(t: u64, node: usize) -> Event {
        Event {
            t_ns: t,
            key: EventKey::compute(node),
            kind: EventKind::ComputeDone { node },
        }
    }

    fn deliver(t: u64, src: usize, dst: usize, fifo: u64) -> Event {
        Event {
            t_ns: t,
            key: EventKey::deliver(src, dst, fifo),
            kind: EventKind::Deliver {
                env: Envelope {
                    src,
                    dst,
                    round: 0,
                    epoch: 0,
                    payload: Msg::Scalar(0.0),
                },
            },
        }
    }

    fn sig(ev: &Event) -> (u64, u8, u32, u32, u64) {
        (ev.t_ns, ev.key.class, ev.key.a, ev.key.b, ev.key.fifo)
    }

    #[test]
    fn same_timestamp_total_order_is_explicit() {
        // The satellite regression pin: equal-time pop order is
        // documented and structural — ComputeDone (node-ascending)
        // before Deliver ((src, dst, fifo)-ascending) — independent of
        // push order.
        let evs = || {
            vec![
                deliver(10, 3, 0, 2),
                compute(50, 5),
                deliver(10, 0, 1, 1),
                compute(10, 2),
                deliver(10, 0, 1, 0),
                compute(10, 1),
            ]
        };
        for rotation in 0..6 {
            let mut q = CalendarQueue::new(16);
            let mut items = evs();
            items.rotate_left(rotation);
            for e in items {
                q.push(e);
            }
            let order: Vec<_> =
                std::iter::from_fn(|| q.pop()).map(|e| sig(&e)).collect();
            assert_eq!(
                order,
                vec![
                    (10, 0, 1, 0, 0), // ComputeDone node 1
                    (10, 0, 2, 0, 0), // ComputeDone node 2
                    (10, 1, 0, 1, 0), // Deliver 0->1 fifo 0
                    (10, 1, 0, 1, 1), // Deliver 0->1 fifo 1
                    (10, 1, 3, 0, 2), // Deliver 3->0
                    (50, 0, 5, 0, 0), // ComputeDone node 5
                ],
                "push rotation {rotation}"
            );
        }
    }

    #[test]
    fn calendar_agrees_with_heap_on_adversarial_workloads() {
        // Deterministic pseudo-random workload mixing same-timestamp
        // clusters, far-future events (overflow), and interleaved
        // push/pop — the calendar queue must reproduce the reference
        // heap's pop sequence exactly.
        use crate::util::rng::Pcg;
        for (seed, width) in
            [(1u64, 1u64), (2, 7), (3, 1000), (4, 1_000_000)]
        {
            let mut rng = Pcg::new(seed);
            let mut cal = CalendarQueue::new(width);
            let mut heap = HeapQueue::new();
            let mut now = 0u64;
            let mut popped = 0usize;
            for step in 0..4_000u64 {
                // Bursts of pushes, never in the past.
                let burst = 1 + (rng.next_u32() % 4) as usize;
                for _ in 0..burst {
                    let dt = match rng.next_u32() % 5 {
                        0 => 0,
                        1 => u64::from(rng.next_u32() % 3),
                        2 => u64::from(rng.next_u32() % 1_000),
                        3 => u64::from(rng.next_u32() % 100_000),
                        _ => u64::from(rng.next_u32()), // far future
                    };
                    let t = now + dt;
                    let ev = if rng.next_u32() % 2 == 0 {
                        compute(t, (rng.next_u32() % 64) as usize)
                    } else {
                        deliver(
                            t,
                            (rng.next_u32() % 64) as usize,
                            (rng.next_u32() % 64) as usize,
                            u64::from(rng.next_u32() % 4),
                        )
                    };
                    let ev2 = Event {
                        t_ns: ev.t_ns,
                        key: ev.key,
                        kind: EventKind::ComputeDone { node: 0 },
                    };
                    cal.push(ev);
                    heap.push(ev2);
                }
                if step % 3 != 0 {
                    for _ in 0..2 {
                        let a = cal.pop();
                        let b = heap.pop();
                        match (&a, &b) {
                            (Some(x), Some(y)) => {
                                assert_eq!(sig(x), sig(y), "seed {seed}");
                                now = x.t_ns;
                                popped += 1;
                            }
                            (None, None) => {}
                            _ => panic!("length divergence (seed {seed})"),
                        }
                    }
                }
            }
            // Drain fully.
            loop {
                let a = cal.pop();
                let b = heap.pop();
                match (&a, &b) {
                    (Some(x), Some(y)) => {
                        assert_eq!(sig(x), sig(y), "seed {seed} drain");
                        popped += 1;
                    }
                    (None, None) => break,
                    _ => panic!("length divergence on drain (seed {seed})"),
                }
            }
            assert!(popped > 4_000, "workload too small: {popped}");
            assert_eq!(cal.len(), 0);
        }
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = CalendarQueue::new(10);
        assert_eq!(q.peek_t(), None);
        q.push(compute(99, 1));
        q.push(compute(7, 2));
        assert_eq!(q.peek_t(), Some(7));
        assert_eq!(q.pop().map(|e| e.t_ns), Some(7));
        assert_eq!(q.peek_t(), Some(99));
        assert_eq!(q.pop().map(|e| e.t_ns), Some(99));
        assert_eq!(q.peek_t(), None);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn wheel_grows_and_preserves_order() {
        let mut q = CalendarQueue::new(1);
        // Far more resident days than the initial wheel: forces grow().
        let n = 10_000u64;
        for i in (0..n).rev() {
            q.push(compute(i * 3 + 1, (i % 13) as usize));
        }
        let mut last = 0u64;
        let mut count = 0;
        while let Some(e) = q.pop() {
            assert!(e.t_ns >= last, "order violated: {} < {last}", e.t_ns);
            last = e.t_ns;
            count += 1;
        }
        assert_eq!(count, n);
    }
}
