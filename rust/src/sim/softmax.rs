//! Artifact-free local model for the virtual-time engine: multinomial
//! logistic regression (softmax) on the synthetic datasets, trained with
//! the same Eq. (6) closed-form prox-SGD step the AOT artifact
//! implements:
//!
//! `w⁺ = (w − η ∇f(w) + η·zsum) / (1 + η·α|N_i|)`
//!
//! (with `alpha_deg = 0` this is plain SGD, exactly like the CNN path).
//! The flat parameter layout matches
//! [`DatasetManifest::synthetic_linear`](crate::model::DatasetManifest::synthetic_linear):
//! a `sample_len × classes` weight matrix at offset 0 (a PowerGossip
//! matrix view) followed by a `classes` bias vector (a PowerGossip
//! rank-1 view).
//!
//! This is what makes the 512-node scale tests, the CI smoke run, and
//! the time-to-accuracy tables runnable with no PJRT artifacts at all;
//! when artifacts exist, the coordinator swaps in the CNN runtime
//! behind the same [`LocalUpdate`](super::LocalUpdate) trait.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::data::{Batcher, Dataset};
use crate::linalg::fused_prox_step_f32;

use super::LocalUpdate;

pub struct SoftmaxLocal {
    train: Dataset,
    test: Arc<Dataset>,
    batcher: Batcher,
    x: Vec<f32>,
    y: Vec<i32>,
    eta: f32,
    local_steps: usize,
    classes: usize,
    sample_len: usize,
    batch: usize,
    // scratch
    logits: Vec<f32>,
    grad: Vec<f32>,
}

impl SoftmaxLocal {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        node: usize,
        train: Dataset,
        test: Arc<Dataset>,
        classes: usize,
        seed: u64,
        eta: f32,
        batch: usize,
        local_steps: usize,
    ) -> Result<SoftmaxLocal> {
        ensure!(local_steps >= 1, "need at least one local step");
        ensure!(train.n >= batch, "node {node}: {} samples < batch {batch}",
                train.n);
        let sample_len = train.sample_len;
        let d = (sample_len + 1) * classes;
        Ok(SoftmaxLocal {
            batcher: Batcher::new(train.n, batch, seed, node),
            x: vec![0.0; batch * sample_len],
            y: vec![0; batch],
            train,
            test,
            eta,
            local_steps,
            classes,
            sample_len,
            batch,
            logits: vec![0.0; classes],
            grad: vec![0.0; d],
        })
    }

    /// Flat parameter dimension for this model shape.
    pub fn dim(sample_len: usize, classes: usize) -> usize {
        (sample_len + 1) * classes
    }

    /// `logits[k] = b_k + Σ_f x_f W[f,k]` for one sample.
    fn forward(&mut self, w: &[f32], xs: &[f32]) {
        let c = self.classes;
        let bias_off = self.sample_len * c;
        self.logits.copy_from_slice(&w[bias_off..bias_off + c]);
        for (f, &xf) in xs.iter().enumerate() {
            if xf == 0.0 {
                continue;
            }
            let row = &w[f * c..(f + 1) * c];
            for (l, &wv) in self.logits.iter_mut().zip(row) {
                *l += xf * wv;
            }
        }
    }

    /// Numerically-stable in-place softmax over `logits`.
    fn softmax_in_place(&mut self) {
        let m = self
            .logits
            .iter()
            .fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for l in self.logits.iter_mut() {
            *l = (*l - m).exp();
            sum += *l;
        }
        for l in self.logits.iter_mut() {
            *l /= sum;
        }
    }

    /// One minibatch prox-SGD step; returns the batch mean loss.
    fn step(&mut self, w: &mut [f32], zsum: &[f32], alpha_deg: f32) -> f64 {
        let c = self.classes;
        let slen = self.sample_len;
        let bias_off = slen * c;
        // Split scratch batch buffers out so `forward` can borrow self.
        let mut xbuf = std::mem::take(&mut self.x);
        let mut ybuf = std::mem::take(&mut self.y);
        self.batcher.next_batch(&self.train, &mut xbuf, &mut ybuf);
        self.grad.iter_mut().for_each(|g| *g = 0.0);
        let mut loss = 0.0f64;
        let inv_b = 1.0 / self.batch as f32;
        for b in 0..self.batch {
            let xs = &xbuf[b * slen..(b + 1) * slen];
            self.forward(w, xs);
            self.softmax_in_place();
            let label = ybuf[b] as usize;
            loss += -(self.logits[label].max(1e-30).ln() as f64);
            for k in 0..c {
                let coeff =
                    (self.logits[k] - if k == label { 1.0 } else { 0.0 })
                        * inv_b;
                if coeff == 0.0 {
                    continue;
                }
                self.grad[bias_off + k] += coeff;
                for (f, &xf) in xs.iter().enumerate() {
                    self.grad[f * c + k] += coeff * xf;
                }
            }
        }
        self.x = xbuf;
        self.y = ybuf;
        // Eq. (6) closed form, fused: per-element expression tree is
        // identical to the scalar loop (pinned against
        // `fused_prox_step_f32_reference` in linalg), so golden hashes
        // replay bit-for-bit.
        let denom = 1.0 + self.eta * alpha_deg;
        fused_prox_step_f32(w, &self.grad, zsum, self.eta, denom);
        loss / self.batch as f64
    }
}

impl LocalUpdate for SoftmaxLocal {
    fn local_round(&mut self, _round: usize, w: &mut [f32], zsum: &[f32],
                   alpha_deg: f32) -> Result<f64> {
        ensure!(
            w.len() == self.grad.len() && zsum.len() == self.grad.len(),
            "parameter dim mismatch: w {} zsum {} model {}",
            w.len(),
            zsum.len(),
            self.grad.len()
        );
        let mut total = 0.0f64;
        for _ in 0..self.local_steps {
            total += self.step(w, zsum, alpha_deg);
        }
        Ok(total / self.local_steps as f64)
    }

    fn evaluate(&mut self, w: &[f32]) -> Result<(f64, f64)> {
        ensure!(w.len() == self.grad.len(), "parameter dim mismatch");
        let test = Arc::clone(&self.test);
        let mut correct = 0usize;
        let mut loss = 0.0f64;
        for i in 0..test.n {
            let xs = test.sample(i);
            self.forward(w, xs);
            self.softmax_in_place();
            let label = test.y[i] as usize;
            loss += -(self.logits[label].max(1e-30).ln() as f64);
            let argmax = self
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            if argmax == label {
                correct += 1;
            }
        }
        Ok((correct as f64 / test.n as f64, loss / test.n as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{build_node_datasets, Partition, SyntheticSpec};

    fn setup(seed: u64) -> (SoftmaxLocal, usize) {
        let spec = SyntheticSpec::for_dataset("tiny", 6, 6, 1, 4, seed);
        let (mut trains, test) = build_node_datasets(
            &spec,
            Partition::Homogeneous,
            1,
            80,
            40,
        );
        let d = SoftmaxLocal::dim(spec.sample_len(), 4);
        let local = SoftmaxLocal::new(
            0,
            trains.remove(0),
            Arc::new(test),
            4,
            seed,
            0.1,
            8,
            2,
        )
        .unwrap();
        (local, d)
    }

    #[test]
    fn loss_decreases_and_accuracy_beats_chance() {
        let (mut local, d) = setup(3);
        let mut w = vec![0.0f32; d];
        let zeros = vec![0.0f32; d];
        let first = local.local_round(0, &mut w, &zeros, 0.0).unwrap();
        let mut last = first;
        for round in 1..20 {
            last = local.local_round(round, &mut w, &zeros, 0.0).unwrap();
        }
        assert!(last < first, "loss did not decrease: {first} -> {last}");
        let (acc, test_loss) = local.evaluate(&w).unwrap();
        assert!(acc > 0.3, "accuracy {acc} not above chance (0.25)");
        assert!(test_loss.is_finite());
    }

    #[test]
    fn prox_term_pulls_towards_zsum_target() {
        // With huge alpha_deg and zsum = alpha_deg * target, w+ ≈ target
        // (mirrors the AOT train_step_prox_shrinks_towards_zsum test).
        let (mut local, d) = setup(4);
        let mut w = vec![0.3f32; d];
        let alpha_deg = 1e6f32;
        let target = 0.125f32;
        let zsum = vec![target * alpha_deg; d];
        local.local_round(0, &mut w, &zsum, alpha_deg).unwrap();
        for &v in &w {
            assert!((v - target).abs() < 1e-3, "{v} vs {target}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut a, d) = setup(5);
        let (mut b, _) = setup(5);
        let zeros = vec![0.0f32; d];
        let mut wa = vec![0.0f32; d];
        let mut wb = vec![0.0f32; d];
        for round in 0..5 {
            let la = a.local_round(round, &mut wa, &zeros, 0.0).unwrap();
            let lb = b.local_round(round, &mut wb, &zeros, 0.0).unwrap();
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        assert_eq!(wa, wb);
    }
}
