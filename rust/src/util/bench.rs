//! In-repo micro-benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`BenchSet`]; output is a column-aligned table of min / mean / p50 /
//! p95 per benchmark, plus optional throughput annotations.

use std::time::Instant;

use super::stats::{human_secs, Summary};
use super::table::Table;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub secs: Summary,
    /// Optional: items (bytes, elements, …) processed per iteration, for a
    /// throughput column.
    pub items_per_iter: Option<f64>,
    pub items_unit: &'static str,
}

/// Time `f` for `iters` iterations after `warmup` iterations, returning
/// per-iteration seconds.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// A named collection of measurements rendered as one table.
#[derive(Default)]
pub struct BenchSet {
    pub title: String,
    measurements: Vec<Measurement>,
}

impl BenchSet {
    pub fn new<S: Into<String>>(title: S) -> Self {
        BenchSet {
            title: title.into(),
            measurements: Vec::new(),
        }
    }

    /// Run and record a benchmark.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize,
                             iters: usize, f: F) {
        let secs = time_it(warmup, iters, f);
        self.measurements.push(Measurement {
            name: name.to_string(),
            iters,
            secs,
            items_per_iter: None,
            items_unit: "",
        });
    }

    /// Run and record a benchmark with a throughput annotation.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        items_per_iter: f64,
        unit: &'static str,
        f: F,
    ) {
        let secs = time_it(warmup, iters, f);
        self.measurements.push(Measurement {
            name: name.to_string(),
            iters,
            secs,
            items_per_iter: Some(items_per_iter),
            items_unit: unit,
        });
    }

    /// Record an externally-computed metric row (e.g. deterministic byte
    /// counts) so a bench table can mix timing and accounting columns.
    pub fn record(&mut self, m: Measurement) {
        self.measurements.push(m);
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Render the results table.
    pub fn render(&self) -> String {
        let mut t = Table::new([
            "benchmark", "iters", "min", "mean", "p50", "p95", "throughput",
        ]);
        for m in &self.measurements {
            let tput = match m.items_per_iter {
                Some(items) if m.secs.mean > 0.0 => {
                    let per_sec = items / m.secs.mean;
                    if m.items_unit == "B" {
                        format!("{}/s", super::stats::human_bytes(per_sec))
                    } else {
                        format!("{per_sec:.3e} {}/s", m.items_unit)
                    }
                }
                _ => "-".to_string(),
            };
            t.row([
                m.name.clone(),
                m.iters.to_string(),
                human_secs(m.secs.min),
                human_secs(m.secs.mean),
                human_secs(m.secs.p50),
                human_secs(m.secs.p95),
                tput,
            ]);
        }
        format!("## {}\n\n{}", self.title, t.render())
    }

    /// Print to stdout (the `cargo bench` entry point convention here).
    pub fn report(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let s = time_it(2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min > 0.0);
        assert!(s.min <= s.mean);
        assert!(s.p50 <= s.p95 + 1e-12);
    }

    #[test]
    fn benchset_renders() {
        let mut set = BenchSet::new("unit");
        set.bench("noop", 1, 5, || {});
        set.bench_throughput("copy", 1, 5, 1024.0, "B", || {
            std::hint::black_box(vec![0u8; 1024]);
        });
        let r = set.render();
        assert!(r.contains("## unit"));
        assert!(r.contains("noop"));
        assert!(r.contains("B/s"));
    }
}
