//! In-repo micro-benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`BenchSet`]; output is a column-aligned table of min / mean / p50 /
//! p95 per benchmark, plus optional throughput annotations.

use std::time::Instant;

use super::stats::{human_secs, Summary};
use super::table::Table;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub secs: Summary,
    /// Optional: items (bytes, elements, …) processed per iteration, for a
    /// throughput column.
    pub items_per_iter: Option<f64>,
    pub items_unit: &'static str,
}

/// Time `f` for `iters` iterations after `warmup` iterations, returning
/// per-iteration seconds.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// A named collection of measurements rendered as one table.
#[derive(Default)]
pub struct BenchSet {
    pub title: String,
    measurements: Vec<Measurement>,
}

impl BenchSet {
    pub fn new<S: Into<String>>(title: S) -> Self {
        BenchSet {
            title: title.into(),
            measurements: Vec::new(),
        }
    }

    /// Run and record a benchmark.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize,
                             iters: usize, f: F) {
        let secs = time_it(warmup, iters, f);
        self.measurements.push(Measurement {
            name: name.to_string(),
            iters,
            secs,
            items_per_iter: None,
            items_unit: "",
        });
    }

    /// Run and record a benchmark with a throughput annotation.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        items_per_iter: f64,
        unit: &'static str,
        f: F,
    ) {
        let secs = time_it(warmup, iters, f);
        self.measurements.push(Measurement {
            name: name.to_string(),
            iters,
            secs,
            items_per_iter: Some(items_per_iter),
            items_unit: unit,
        });
    }

    /// Record an externally-computed metric row (e.g. deterministic byte
    /// counts) so a bench table can mix timing and accounting columns.
    pub fn record(&mut self, m: Measurement) {
        self.measurements.push(m);
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Render the results table.
    pub fn render(&self) -> String {
        let mut t = Table::new([
            "benchmark", "iters", "min", "mean", "p50", "p95", "throughput",
        ]);
        for m in &self.measurements {
            let tput = match m.items_per_iter {
                Some(items) if m.secs.mean > 0.0 => {
                    let per_sec = items / m.secs.mean;
                    if m.items_unit == "B" {
                        format!("{}/s", super::stats::human_bytes(per_sec))
                    } else {
                        format!("{per_sec:.3e} {}/s", m.items_unit)
                    }
                }
                _ => "-".to_string(),
            };
            t.row([
                m.name.clone(),
                m.iters.to_string(),
                human_secs(m.secs.min),
                human_secs(m.secs.mean),
                human_secs(m.secs.p50),
                human_secs(m.secs.p95),
                tput,
            ]);
        }
        format!("## {}\n\n{}", self.title, t.render())
    }

    /// Print to stdout (the `cargo bench` entry point convention here).
    pub fn report(&self) {
        println!("{}", self.render());
    }
}

// ---------------------------------------------------------------------
// Machine-readable output (`--json` / `--check`)
// ---------------------------------------------------------------------

/// Accumulates rows from one or more [`BenchSet`]s into a flat JSON
/// document (hand-rolled: the only dependency budget here is
/// `anyhow`).  Row names are prefixed with their set title
/// (`"<title>/<name>"`), so a whole bench binary serializes into one
/// list, diffable across commits — `BENCH_sim_scale.json` is this
/// format, and the CI regression gate parses it back with
/// [`parse_mean_secs`].
#[derive(Default)]
pub struct JsonReport {
    rows: Vec<String>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

impl JsonReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append every measurement of `set` as a JSON row.
    pub fn add_set(&mut self, set: &BenchSet) {
        for m in set.measurements() {
            let items = match m.items_per_iter {
                Some(v) => format!("{v}"),
                None => "null".to_string(),
            };
            self.rows.push(format!(
                "{{\"name\":\"{}/{}\",\"iters\":{},\"min_secs\":{},\
                 \"mean_secs\":{},\"p50_secs\":{},\"p95_secs\":{},\
                 \"items_per_iter\":{},\"items_unit\":\"{}\"}}",
                json_escape(&set.title),
                json_escape(&m.name),
                m.iters,
                m.secs.min,
                m.secs.mean,
                m.secs.p50,
                m.secs.p95,
                items,
                json_escape(m.items_unit),
            ));
        }
    }

    /// The complete document: `{"rows":[...]}`, one row per line.
    pub fn render(&self) -> String {
        let mut out = String::from("{\"rows\":[\n");
        for (i, r) in self.rows.iter().enumerate() {
            out.push_str(r);
            if i + 1 < self.rows.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]}\n");
        out
    }
}

/// Extract `(name, mean_secs)` pairs from a [`JsonReport`] document.
///
/// This is a purpose-built scanner for the exact shape `render()`
/// emits (plus whitespace tolerance), not a general JSON parser — it
/// reads the `"name"` and `"mean_secs"` fields of each row object and
/// ignores everything else.
pub fn parse_mean_secs(doc: &str) -> Result<Vec<(String, f64)>, String> {
    let mut out = Vec::new();
    let mut rest = doc;
    while let Some(at) = rest.find("\"name\":\"") {
        rest = &rest[at + 8..];
        let mut name = String::new();
        let mut chars = rest.char_indices();
        let mut end = None;
        while let Some((i, c)) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some((_, 'n')) => name.push('\n'),
                    Some((_, e)) => name.push(e),
                    None => return Err("truncated escape".into()),
                },
                '"' => {
                    end = Some(i);
                    break;
                }
                c => name.push(c),
            }
        }
        let end = end.ok_or_else(|| "unterminated name".to_string())?;
        rest = &rest[end + 1..];
        let at = rest
            .find("\"mean_secs\":")
            .ok_or_else(|| format!("row `{name}` has no mean_secs"))?;
        let num = rest[at + 12..]
            .split(|c: char| c == ',' || c == '}')
            .next()
            .unwrap_or("")
            .trim();
        let mean: f64 = num
            .parse()
            .map_err(|e| format!("row `{name}`: bad mean `{num}`: {e}"))?;
        out.push((name, mean));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let s = time_it(2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min > 0.0);
        assert!(s.min <= s.mean);
        assert!(s.p50 <= s.p95 + 1e-12);
    }

    #[test]
    fn json_report_round_trips_means() {
        let mut set = BenchSet::new("scale");
        set.bench("nodes 64", 0, 3, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        set.bench_throughput("nodes \"512\"", 0, 3, 2.0, "ev", || {});
        let mut rep = JsonReport::new();
        rep.add_set(&set);
        let doc = rep.render();
        let means = parse_mean_secs(&doc).unwrap();
        assert_eq!(means.len(), 2);
        assert_eq!(means[0].0, "scale/nodes 64");
        assert_eq!(means[1].0, "scale/nodes \"512\"");
        for ((name, mean), m) in means.iter().zip(set.measurements()) {
            assert!((mean - m.secs.mean).abs() <= 1e-12 * m.secs.mean,
                    "{name}: {mean} vs {}", m.secs.mean);
        }
    }

    #[test]
    fn benchset_renders() {
        let mut set = BenchSet::new("unit");
        set.bench("noop", 1, 5, || {});
        set.bench_throughput("copy", 1, 5, 1024.0, "B", || {
            std::hint::black_box(vec![0u8; 1024]);
        });
        let r = set.render();
        assert!(r.contains("## unit"));
        assert!(r.contains("noop"));
        assert!(r.contains("B/s"));
    }
}
