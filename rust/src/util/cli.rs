//! Minimal CLI argument parser (clap is not available offline).
//!
//! Grammar: `repro <subcommand> [--key value]... [--flag]...`
//! Values parse via `FromStr`; unknown keys are reported at the end so
//! typos fail loudly instead of silently using defaults.

use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional argument (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit iterator (used by tests).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut args = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(key) = item.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(item);
            } else {
                args.positional.push(item);
            }
        }
        args
    }

    /// Typed option with default.
    pub fn get<T: FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        self.consumed.borrow_mut().push(key.to_string());
        match self.options.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key} {v}: bad value ({e:?})")),
            None => default,
        }
    }

    /// Typed option, `None` when absent.
    pub fn get_opt<T: FromStr>(&self, key: &str) -> Option<T>
    where
        T::Err: std::fmt::Debug,
    {
        self.consumed.borrow_mut().push(key.to_string());
        self.options.get(key).map(|v| {
            v.parse()
                .unwrap_or_else(|e| panic!("--{key} {v}: bad value ({e:?})"))
        })
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.consumed.borrow_mut().push(key.to_string());
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Boolean flag.
    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Keys that were provided but never read — call after all `get`s.
    pub fn unknown_keys(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("table1 --epochs 20 --dataset fashion --quiet");
        assert_eq!(a.command.as_deref(), Some("table1"));
        assert_eq!(a.get::<usize>("epochs", 5), 20);
        assert_eq!(a.get_str("dataset", "cifar"), "fashion");
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --k-frac=0.1");
        assert!((a.get::<f64>("k-frac", 0.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get::<usize>("epochs", 7), 7);
        assert_eq!(a.get_opt::<usize>("epochs"), None);
    }

    #[test]
    fn positional_args() {
        let a = parse("theory extra1 extra2 --n 4");
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
        assert_eq!(a.get::<usize>("n", 0), 4);
    }

    #[test]
    fn unknown_keys_reported() {
        let a = parse("run --epochs 5 --typo-key 3");
        let _ = a.get::<usize>("epochs", 1);
        assert_eq!(a.unknown_keys(), vec!["typo-key".to_string()]);
    }

    #[test]
    #[should_panic]
    fn bad_value_panics() {
        let a = parse("run --epochs notanumber");
        let _ = a.get::<usize>("epochs", 1);
    }
}
