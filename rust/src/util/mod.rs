//! Utility substrates built in-repo (the offline vendor set has no rand /
//! clap / serde / criterion / proptest — see DESIGN.md §2).

pub mod bench;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
