//! Mini property-based testing harness (proptest is not available
//! offline).
//!
//! `check` runs a property over `cases` seeded random inputs; on failure
//! it retries with progressively simpler size hints (a light-weight
//! shrinking pass) and reports the failing seed so the case is exactly
//! reproducible with [`check_seed`].

use super::rng::Pcg;

/// Context handed to a property: a seeded RNG plus a size hint in
/// `[1, max_size]` that grows over the run (small cases first).
pub struct Ctx {
    pub rng: Pcg,
    pub size: usize,
    pub seed: u64,
}

impl Ctx {
    /// A vector of `n` standard-normal f32 values.
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal_f32()).collect()
    }

    /// A vector of `n` standard-normal f64 values.
    pub fn vec_f64(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }
}

/// Outcome of a single case.
pub type CaseResult = Result<(), String>;

/// Run `property` over `cases` random inputs. Panics (with the failing
/// seed and message) on the first failure after a simplification pass.
pub fn check<F: Fn(&mut Ctx) -> CaseResult>(
    name: &str,
    cases: usize,
    max_size: usize,
    property: F,
) {
    let base = fxhash(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        // Size ramps up: early cases are small, later cases large.
        let size = 1 + (max_size - 1) * case / cases.max(1);
        if let Err(msg) = run_one(&property, seed, size) {
            // Shrinking-lite: try the same seed at smaller sizes to
            // report the simplest reproduction.
            let mut best = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                if let Err(m) = run_one(&property, seed, s) {
                    best = (s, m);
                    if s == 1 {
                        break;
                    }
                }
                if s == 1 {
                    break;
                }
                s /= 2;
            }
            panic!(
                "property `{name}` failed (seed={seed}, size={}): {}\n\
                 reproduce with util::prop::check_seed(\"{name}\", {seed}, {})",
                best.0, best.1, best.0
            );
        }
    }
}

/// Re-run a single failing case.
pub fn check_seed<F: Fn(&mut Ctx) -> CaseResult>(
    name: &str,
    seed: u64,
    size: usize,
    property: F,
) {
    if let Err(msg) = run_one(&property, seed, size) {
        panic!("property `{name}` failed at seed={seed}: {msg}");
    }
}

fn run_one<F: Fn(&mut Ctx) -> CaseResult>(
    property: &F,
    seed: u64,
    size: usize,
) -> CaseResult {
    let mut ctx = Ctx {
        rng: Pcg::new(seed),
        size,
        seed,
    };
    property(&mut ctx)
}

/// Assert helper producing `CaseResult`-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// FNV-1a on the property name, for a stable per-property seed base.
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0usize);
        check("always-true", 32, 100, |_ctx| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 32);
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 4, 10, |_ctx| Err("nope".to_string()));
    }

    #[test]
    fn sizes_ramp_up() {
        let max_seen = std::cell::Cell::new(0usize);
        let min_seen = std::cell::Cell::new(usize::MAX);
        check("size-ramp", 50, 64, |ctx| {
            max_seen.set(max_seen.get().max(ctx.size));
            min_seen.set(min_seen.get().min(ctx.size));
            Ok(())
        });
        assert_eq!(min_seen.get(), 1);
        assert!(max_seen.get() > 32);
    }

    #[test]
    fn deterministic_inputs_per_seed() {
        let first = std::cell::RefCell::new(Vec::new());
        check("det", 1, 8, |ctx| {
            *first.borrow_mut() = ctx.vec_f32(8);
            Ok(())
        });
        let second = std::cell::RefCell::new(Vec::new());
        check("det", 1, 8, |ctx| {
            *second.borrow_mut() = ctx.vec_f32(8);
            Ok(())
        });
        assert_eq!(*first.borrow(), *second.borrow());
    }
}
