//! Deterministic PRNG substrate: PCG-XSH-RR 64/32 with splitmix seeding
//! and counter-based stream derivation.
//!
//! Stream derivation is load-bearing for the paper's shared-seed trick
//! (§3.2 / Alg. 1 lines 5–6): node `i` and node `j` both derive the mask
//! RNG for edge `(i, j)` at round `r` as `Pcg::derive(seed, &[EDGE_MASK,
//! edge_id, round, dir])` — identical on both endpoints, so the sparsity
//! pattern ω never crosses the wire.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small state, excellent statistical
/// quality, and — unlike xorshift — a principled multi-stream story via
/// the odd increment.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// splitmix64 — used to expand seeds and hash derivation tuples.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

impl Pcg {
    /// New generator from a 64-bit seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0)
    }

    /// New generator on an explicit stream.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (splitmix64(stream.wrapping_add(0xda3e_39cb_94b9_5bdb)) << 1) | 1,
        };
        rng.state = splitmix64(seed);
        rng.next_u32();
        rng
    }

    /// Counter-based derivation: a generator uniquely determined by
    /// `(seed, path)`. Both endpoints of an edge derive identical mask
    /// generators from the same path — the shared-seed optimization.
    pub fn derive(seed: u64, path: &[u64]) -> Self {
        let mut h = splitmix64(seed ^ 0x243F_6A88_85A3_08D3);
        for &p in path {
            h = splitmix64(h ^ splitmix64(p.wrapping_add(0x9E37_79B9)));
        }
        Pcg::with_stream(h, splitmix64(h ^ 0xB752_1E95))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa entropy.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53 bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's nearly-divisionless method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this RNG is not on any hot path that cares).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang squeeze (2000); the shape < 1
    /// case goes through the Gamma(shape + 1) boost `G(a) = G(a+1)·U^{1/a}`.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0 && shape.is_finite(), "gamma shape {shape}");
        if shape < 1.0 {
            let boost = self.gamma(shape + 1.0);
            // U ∈ (0, 1): f64() can return exactly 0, which would stick
            // the draw at 0 for every shape.
            let mut u = self.f64();
            while u <= 0.0 {
                u = self.f64();
            }
            return boost * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(α·1_k) draw: `k` proportions summing to 1.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        assert!(k > 0);
        let g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // All draws underflowed to 0 (tiny α): fall back to a point
            // mass on a uniformly-chosen coordinate — the α → 0 limit.
            let mut p = vec![0.0; k];
            p[self.below(k)] = 1.0;
            return p;
        }
        g.iter().map(|&x| x / sum).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Domain tags for [`Pcg::derive`] paths, so independent uses can never
/// collide on the same stream.
pub mod streams {
    /// Per-edge, per-round compression mask (the paper's ω).
    pub const EDGE_MASK: u64 = 1;
    /// Dataset generation.
    pub const DATA: u64 = 2;
    /// Per-node batch shuffling.
    pub const BATCH: u64 = 3;
    /// Model initialization (quadratic substrate).
    pub const INIT: u64 = 4;
    /// PowerGossip warm-start vectors.
    pub const POWER: u64 = 5;
    /// Heterogeneous class assignment.
    pub const PARTITION: u64 = 6;
    /// Virtual-time link model (drop/retransmit draws).
    pub const LINK: u64 = 7;
    /// Random edge-churn rule (`ChurnSchedule`): per-(edge, slot) draws.
    pub const CHURN: u64 = 8;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn derive_is_path_sensitive() {
        let mut a = Pcg::derive(7, &[1, 2, 3]);
        let mut b = Pcg::derive(7, &[1, 2, 4]);
        let mut c = Pcg::derive(7, &[1, 2, 3]);
        assert_eq!(a.next_u64(), c.next_u64());
        let mut a2 = Pcg::derive(7, &[1, 2, 3]);
        assert_ne!(a2.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg::new(3);
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut rng = Pcg::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Pcg::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg::new(17);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn gamma_moments() {
        // Gamma(a, 1): mean a, variance a — check both branches of the
        // sampler (a < 1 boost, a ≥ 1 squeeze).
        for a in [0.1, 0.5, 1.0, 3.5] {
            let mut rng = Pcg::new(31);
            let n = 100_000;
            let xs: Vec<f64> = (0..n).map(|_| rng.gamma(a)).collect();
            assert!(xs.iter().all(|&x| x >= 0.0));
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / n as f64;
            assert!((mean - a).abs() < 0.05 * (1.0 + a), "a={a} mean={mean}");
            assert!((var - a).abs() < 0.15 * (1.0 + a), "a={a} var={var}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_concentrates() {
        let mut rng = Pcg::new(37);
        let p = rng.dirichlet(1.0, 10);
        assert_eq!(p.len(), 10);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&x| x >= 0.0));
        // Large α concentrates near uniform; small α near a vertex.
        let mut big = Pcg::new(41);
        let pb = big.dirichlet(1e4, 10);
        assert!(pb.iter().all(|&x| (x - 0.1).abs() < 0.02), "{pb:?}");
        let mut small = Pcg::new(43);
        let mx = (0..20)
            .map(|_| {
                small
                    .dirichlet(0.05, 10)
                    .into_iter()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 20.0;
        assert!(mx > 0.8, "α=0.05 mean max share {mx}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg::new(23);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.1)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.1).abs() < 0.01, "rate={rate}");
    }
}
