//! Small numeric helpers: summary statistics and human-readable units.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute from a sample (not required to be sorted).
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
        }
    }
}

/// Percentile of an ascending-sorted slice, linear interpolation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// `1234567` -> `"1206 KB"` etc. Uses KB = 1024 B and keeps KB up to
/// tens of MB to match the paper's table units (e.g. `18677 KB`).
pub fn human_bytes(bytes: f64) -> String {
    if bytes < 1024.0 {
        format!("{bytes:.0} B")
    } else if bytes < 32.0 * 1024.0 * 1024.0 {
        format!("{:.0} KB", bytes / 1024.0)
    } else {
        format!("{:.1} MB", bytes / (1024.0 * 1024.0))
    }
}

/// `12.3456` seconds -> `"12.35 s"`, small values in ms/us.
pub fn human_secs(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.1} us", secs * 1e6)
    }
}

/// Geometric mean of per-round contraction factors between consecutive
/// error norms: `(e_last / e_first)^(1/(n-1))`. Used by the Theorem-1
/// rate checker.
pub fn empirical_rate(errors: &[f64]) -> f64 {
    assert!(errors.len() >= 2);
    let first = errors[0].max(1e-300);
    let last = errors[errors.len() - 1].max(1e-300);
    (last / first).powf(1.0 / (errors.len() - 1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 5.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(5336.0 * 1024.0), "5336 KB");
        assert_eq!(human_bytes(18677.0 * 1024.0), "18677 KB");
        assert_eq!(human_bytes(48.0 * 1024.0 * 1024.0), "48.0 MB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(2.0), "2.00 s");
        assert_eq!(human_secs(0.0021), "2.10 ms");
        assert_eq!(human_secs(12e-6), "12.0 us");
    }

    #[test]
    fn rate_of_geometric_sequence() {
        // e_r = 0.5^r: rate must be 0.5.
        let errs: Vec<f64> = (0..10).map(|r| 0.5f64.powi(r)).collect();
        assert!((empirical_rate(&errs) - 0.5).abs() < 1e-12);
    }
}
