//! Markdown-table and CSV emitters for experiment reports (serde-free).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned markdown table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Self {
        Table {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width"
        );
        self.rows.push(cells);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a column-aligned GitHub-flavored markdown table.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            out.push('|');
            for i in 0..ncols {
                let _ = write!(out, " {:<w$} |", cells[i], w = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{:-<w$}|", "", w = w + 2);
        }
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Write as CSV (headers + rows). Cells containing commas are quoted.
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        let quote = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        writeln!(
            f,
            "{}",
            self.headers.iter().map(|h| quote(h)).collect::<Vec<_>>().join(",")
        )?;
        for row in &self.rows {
            writeln!(
                f,
                "{}",
                row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
            )?;
        }
        Ok(())
    }
}

/// Format helper matching the paper's communication-cost columns:
/// `"5336 KB (x1.0)"`.
pub fn kb_with_ratio(bytes: f64, baseline_bytes: f64) -> String {
    let kb = bytes / 1024.0;
    if baseline_bytes > 0.0 && bytes > 0.0 {
        format!("{:.0} KB (x{:.1})", kb, baseline_bytes / bytes)
    } else if bytes > 0.0 {
        format!("{kb:.0} KB")
    } else {
        "-".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(["method", "acc"]);
        t.row(["D-PSGD", "84.1"]);
        t.row(["C-ECL (1%)", "84.0"]);
        let r = t.render();
        assert!(r.contains("| method     | acc  |"));
        assert!(r.lines().count() == 4);
        for line in r.lines() {
            assert_eq!(line.len(), r.lines().next().unwrap().len());
        }
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("cecl_table_test");
        let path = dir.join("t.csv");
        let mut t = Table::new(["x", "y"]);
        t.row(["1", "2,3"]);
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x,y\n1,\"2,3\"\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(
            kb_with_ratio(1024.0 * 100.0, 1024.0 * 1000.0),
            "100 KB (x10.0)"
        );
        assert_eq!(kb_with_ratio(0.0, 123.0), "-");
    }
}
