//! Convergence-behaviour suite on the convex-quadratic substrate — fast,
//! exact, artifact-free checks of the paper's algorithmic claims — plus
//! the rival-baseline head-to-head: LEAD's primal-dual iteration on the
//! quadratic network, and the Dirichlet-skew acceptance run where C-ECL
//! beats CHOCO-SGD at matched bytes per round.

use std::sync::Arc;

use cecl::algorithms::{AlgorithmSpec, BuildCtx, DualPath, LeadNode,
                       NodeStateMachine, RoundPolicy};
use cecl::comm::{Msg, Outbox};
use cecl::compress::{CodecSpec, WireMode};
use cecl::coordinator::{run_simulated_native, ExecMode, ExperimentSpec};
use cecl::data::Partition;
use cecl::graph::{Graph, TopologyView};
use cecl::linalg;
use cecl::model::Manifest;
use cecl::quadratic::{
    delta_of, rate_bound, run_cecl, tau_threshold, theta_domain, DualRule,
    QuadraticNetwork,
};
use cecl::sim::SimConfig;
use cecl::util::stats::empirical_rate;

fn network(seed: u64) -> (QuadraticNetwork, Graph) {
    let graph = Graph::ring(8);
    (QuadraticNetwork::random(8, 16, 30, 0.5, 0.6, seed), graph)
}

#[test]
fn ecl_reaches_consensus_at_optimum() {
    let (net, graph) = network(1);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let errors = run_cecl(&net, &graph, alpha, 1.0, 1.0, 300, 1,
                          DualRule::CompressDiff);
    assert!(
        errors.last().unwrap() < &(errors[0] * 1e-8),
        "did not converge: {:?}",
        errors.last()
    );
}

#[test]
fn cecl_converges_across_seeds_and_compressions() {
    for seed in [2, 3, 4] {
        let (net, graph) = network(seed);
        let alpha = net.best_alpha(&graph).expect("non-empty graph");
        let delta = net.delta(alpha, &graph).expect("non-empty graph");
        for k in [0.5, 0.8] {
            if k < tau_threshold(delta) {
                continue;
            }
            let errors = run_cecl(&net, &graph, alpha, 1.0, k, 300, seed,
                                  DualRule::CompressDiff);
            assert!(
                errors.last().unwrap() < &(errors[0] * 1e-3),
                "seed {seed} k {k}: {:?}",
                errors.last()
            );
        }
    }
}

#[test]
fn compression_slows_but_does_not_break() {
    let (net, graph) = network(5);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let rate_at = |k: f64| {
        let e = run_cecl(&net, &graph, alpha, 1.0, k, 200, 5,
                         DualRule::CompressDiff);
        empirical_rate(&e[40..])
    };
    let r1 = rate_at(1.0);
    let r05 = rate_at(0.5);
    assert!(r1 < 1.0 && r05 < 1.0);
    assert!(r1 <= r05 + 0.02, "full {r1} vs half {r05}");
}

#[test]
fn naive_rule_fails_where_cecl_succeeds() {
    // The §3.2 motivation: Eq. (11) stalls at a noise floor, Eq. (13)
    // drives the error to ~0.
    let (net, graph) = network(6);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let diff = run_cecl(&net, &graph, alpha, 1.0, 0.5, 250, 6,
                        DualRule::CompressDiff);
    let naive = run_cecl(&net, &graph, alpha, 1.0, 0.5, 250, 6,
                         DualRule::CompressY);
    assert!(diff.last().unwrap() * 20.0 < *naive.last().unwrap());
}

#[test]
fn works_on_every_paper_topology() {
    let net = QuadraticNetwork::random(8, 12, 24, 0.5, 0.5, 7);
    for graph in [
        Graph::chain(8),
        Graph::ring(8),
        Graph::multiplex_ring(8),
        Graph::complete(8),
    ] {
        let alpha = net.best_alpha(&graph).expect("non-empty graph");
        let errors = run_cecl(&net, &graph, alpha, 1.0, 0.8, 250, 7,
                              DualRule::CompressDiff);
        assert!(
            errors.last().unwrap() < &(errors[0] * 1e-3),
            "topology deg[{:?},{:?}]: final {:?}",
            graph.min_degree(),
            graph.max_degree(),
            errors.last()
        );
    }
}

#[test]
fn delta_and_domain_formulas_consistent() {
    // δ(α*) minimizes the two-branch max; the θ domain at the threshold
    // collapses onto a point near 1... (Lemma 6 arithmetic).
    let (net, graph) = network(8);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let delta = net.delta(alpha, &graph).expect("non-empty graph");
    assert!((0.0..1.0).contains(&delta));
    let thr = tau_threshold(delta);
    // Just above the threshold the domain exists and is tight around 1.
    let (lo, hi) = theta_domain(thr + 1e-6, delta).expect("non-empty");
    assert!(lo < 1.0 + 1e-3 && hi > 1.0 - 1e-3);
    // Far above, it widens.
    let (lo2, hi2) = theta_domain(1.0, delta).unwrap();
    assert!(lo2 <= lo && hi2 >= hi);
    // delta_of is continuous in alpha around alpha*.
    let d1 = delta_of(alpha * 1.001, net.l_smooth, net.mu,
                      graph.max_degree().unwrap() as f64,
                      graph.min_degree().unwrap() as f64);
    assert!((d1 - delta).abs() < 1e-2);
}

#[test]
fn rate_bound_theorem1_structure() {
    // ρ(θ=1, τ=1, δ) = δ (Corollary 1 with θ = 1 — the Peaceman-Rachford
    // point), and ρ grows as √(1−τ) scales the compression penalty.
    for delta in [0.1, 0.5, 0.9] {
        assert!((rate_bound(1.0, 1.0, delta) - delta).abs() < 1e-12);
    }
    let d = 0.4;
    let penalty = |tau: f64| rate_bound(1.0, tau, d) - d;
    assert!(penalty(1.0).abs() < 1e-12);
    let p075 = penalty(0.75);
    let p05 = penalty(0.5);
    // penalty(τ) = √(1−τ)(1 + δ): check exact values.
    assert!((p075 - 0.25f64.sqrt() * (1.0 + d)).abs() < 1e-12);
    assert!((p05 - 0.5f64.sqrt() * (1.0 + d)).abs() < 1e-12);
}

/// A d = d_pad = 16 manifest matching the quadratic network's
/// dimension, so real `NodeStateMachine`s drive on the exact substrate.
fn quadratic_manifest() -> cecl::model::DatasetManifest {
    Manifest::parse(
        "version 1\nsmoke s\ndataset t\nd 16\nd_pad 16\ninput 2 2 1\n\
         classes 2\nbatch 2\neval_batch 2\ntrain_step a\neval_step b\n\
         dual_update c\ninit_w d\nlayer l 4 4\nend\n",
        std::path::Path::new("/x"),
    )
    .unwrap()
    .dataset("t")
    .unwrap()
    .clone()
}

/// One synchronous exchange round of real LEAD machines, driven by
/// hand: round_begin everywhere, deliver in ascending sender order,
/// round_end everywhere.
fn lead_round(nodes: &mut [LeadNode], ws: &mut [Vec<f32>], round: usize,
              view: &TopologyView) {
    let n = nodes.len();
    let mut queued: Vec<Vec<(usize, Msg)>> = Vec::with_capacity(n);
    for i in 0..n {
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut nodes[i], round, view, &mut ws[i],
                                      &mut out)
            .unwrap();
        queued.push(out.drain().collect());
    }
    for (src, msgs) in queued.into_iter().enumerate() {
        for (to, msg) in msgs {
            let mut out = Outbox::new();
            NodeStateMachine::on_message(&mut nodes[to], round, src, msg,
                                         view, &mut ws[to], &mut out)
                .unwrap();
            assert!(out.is_empty(), "LEAD is single-phase");
        }
    }
    for i in 0..n {
        assert!(nodes[i].round_complete());
        NodeStateMachine::round_end(&mut nodes[i], round, view, &mut ws[i])
            .unwrap();
    }
}

#[test]
fn lead_converges_on_the_quadratic_network() {
    // The LEAD rival as a real state machine on the convex-quadratic
    // substrate: per round, every node takes the Eq. (6)-style local
    // step z = w − η∇f(w) + η·(−d) (alpha_deg = 0, zsum = −d), then
    // the machines exchange compressed z-estimates and apply the
    // primal/dual corrections.  With the identity codec the stacked
    // distance to the global optimum w* must fall by orders of
    // magnitude — LEAD solves the heterogeneous consensus problem
    // exactly, unlike plain gossip averaging.
    let (net, graph) = network(11);
    let graph = Arc::new(graph);
    let n = graph.n();
    let dim = net.dim;
    let manifest = quadratic_manifest();
    assert_eq!(manifest.d_pad, dim, "manifest must match the network");
    let eta = 0.25 / net.l_smooth;
    let mut nodes: Vec<LeadNode> = (0..n)
        .map(|i| {
            let ctx = BuildCtx {
                node: i,
                graph: Arc::clone(&graph),
                manifest: manifest.clone(),
                seed: 11,
                eta: eta as f32,
                local_steps: 1,
                rounds_per_epoch: 1,
                dual_path: DualPath::Native,
                runtime: None,
                round_policy: RoundPolicy::Sync,
            };
            LeadNode::new(&ctx, CodecSpec::Identity).unwrap()
        })
        .collect();
    let mut ws: Vec<Vec<f32>> = vec![vec![0.0; dim]; n];
    let err = |ws: &[Vec<f32>]| -> f64 {
        ws.iter()
            .map(|w| {
                w.iter()
                    .zip(&net.w_star)
                    .map(|(&wf, &s)| (wf as f64 - s).powi(2))
                    .sum::<f64>()
            })
            .sum()
    };
    let e0 = err(&ws);
    assert!(e0 > 0.0, "w* must be nonzero for the test to have teeth");
    let view = TopologyView::full(graph.edges().len());
    for round in 0..600 {
        for i in 0..n {
            let wf: Vec<f64> = ws[i].iter().map(|&v| v as f64).collect();
            let hw = net.nodes[i].hess.matvec(&wf);
            let nd: Vec<f32> =
                NodeStateMachine::zsum(&nodes[i]).unwrap().to_vec();
            for k in 0..dim {
                let grad = hw[k] - net.nodes[i].btc[k];
                ws[i][k] = (wf[k] - eta * grad) as f32 + (eta as f32) * nd[k];
            }
        }
        lead_round(&mut nodes, &mut ws, round, &view);
    }
    let e_final = err(&ws);
    assert!(e_final.is_finite(), "LEAD diverged");
    assert!(
        e_final < e0 * 1e-2,
        "LEAD stalled: {e_final} vs initial {e0}"
    );
    // Consensus: every pair of nodes agrees to fine precision relative
    // to the remaining optimality error.
    let spread: f64 = (1..n)
        .map(|i| {
            ws[i]
                .iter()
                .zip(&ws[0])
                .map(|(&a, &b)| (a as f64 - b as f64).powi(2))
                .sum::<f64>()
        })
        .sum();
    assert!(
        spread < e0 * 1e-2,
        "LEAD nodes never reached consensus: spread {spread}"
    );
}

#[test]
fn cecl_beats_choco_at_matched_bytes_under_dirichlet_skew() {
    // The acceptance scenario: at heavy label skew (dirichlet:0.1) and
    // byte-for-byte matched communication (rand_k:0.1 frames on both
    // sides, no dense warmup), operator splitting must clear the
    // accuracy target while CHOCO-SGD's gossip averaging falls
    // measurably short — the paper's headline, reproduced end to end
    // on the virtual-time engine at a fixed seed.
    let graph = Graph::ring(8);
    let run = |alg: AlgorithmSpec| {
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: alg,
            epochs: 8,
            nodes: 8,
            train_per_node: 100,
            test_size: 200,
            partition: Partition::Dirichlet { alpha: 0.1 },
            local_steps: 2,
            eta: 0.1,
            eval_every: 8,
            seed: 23,
            exec: ExecMode::Simulated(SimConfig::default()),
            rounds: RoundPolicy::Sync,
            ..Default::default()
        };
        run_simulated_native(&spec, &graph).unwrap()
    };
    let cecl = run(AlgorithmSpec::CEcl {
        k_frac: 0.1,
        theta: 1.0,
        dense_first_epoch: false,
    });
    let choco = run(AlgorithmSpec::Choco {
        codec: CodecSpec::RandK { k_frac: 0.1, mode: WireMode::Explicit },
    });
    // Matched communication: identical codec, schedule, and graph give
    // identical wire bytes — the comparison isolates the algorithm.
    assert_eq!(
        cecl.total_bytes, choco.total_bytes,
        "bytes/round must match for a fair head-to-head"
    );
    // Fixed-seed determinism of the whole scenario.
    let replay = run(AlgorithmSpec::CEcl {
        k_frac: 0.1,
        theta: 1.0,
        dense_first_epoch: false,
    });
    assert_eq!(replay.final_accuracy.to_bits(), cecl.final_accuracy.to_bits());
    // C-ECL clears the target; CHOCO-SGD falls measurably short.
    assert!(
        cecl.final_accuracy > 0.15,
        "C-ECL accuracy {} below target under dirichlet:0.1",
        cecl.final_accuracy
    );
    assert!(
        cecl.final_accuracy > choco.final_accuracy + 0.03,
        "C-ECL {} not measurably above CHOCO-SGD {} at matched bytes",
        cecl.final_accuracy,
        choco.final_accuracy
    );
}

#[test]
fn heterogeneity_hurts_gossip_not_prox() {
    // Convex analogue of the paper's headline: one exact-averaging
    // gossip round cannot reach the global optimum under heterogeneity
    // (consensus of local optima != global optimum), while the
    // primal-dual iteration converges to it exactly.
    let (net, graph) = network(9);
    // "Gossip at convergence": each node at its LOCAL optimum, then
    // repeated MH averaging converges to the mean of local optima.
    let dim = net.dim;
    let mut locals: Vec<Vec<f64>> = net
        .nodes
        .iter()
        .map(|n| {
            cecl::linalg::Cholesky::new(&n.hess).unwrap().solve(&n.btc)
        })
        .collect();
    let w = graph.mh_weights();
    for _ in 0..500 {
        let prev = locals.clone();
        for i in 0..graph.n() {
            let mut acc = vec![0.0; dim];
            for j in 0..graph.n() {
                if w[i][j] != 0.0 {
                    linalg::axpy(w[i][j], &prev[j], &mut acc);
                }
            }
            locals[i] = acc;
        }
    }
    let gossip_err = linalg::norm2(&linalg::sub(&locals[0], &net.w_star));
    let cecl_errors = run_cecl(&net, &graph, net.best_alpha(&graph).expect("non-empty graph"), 1.0,
                               1.0, 300, 9, DualRule::CompressDiff);
    let prox_err = *cecl_errors.last().unwrap();
    assert!(
        prox_err < gossip_err / 100.0,
        "prox {prox_err} vs gossip-mean bias {gossip_err}"
    );
}
