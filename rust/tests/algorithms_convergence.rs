//! Convergence-behaviour suite on the convex-quadratic substrate — fast,
//! exact, artifact-free checks of the paper's algorithmic claims.

use cecl::graph::Graph;
use cecl::linalg;
use cecl::quadratic::{
    delta_of, rate_bound, run_cecl, tau_threshold, theta_domain, DualRule,
    QuadraticNetwork,
};
use cecl::util::stats::empirical_rate;

fn network(seed: u64) -> (QuadraticNetwork, Graph) {
    let graph = Graph::ring(8);
    (QuadraticNetwork::random(8, 16, 30, 0.5, 0.6, seed), graph)
}

#[test]
fn ecl_reaches_consensus_at_optimum() {
    let (net, graph) = network(1);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let errors = run_cecl(&net, &graph, alpha, 1.0, 1.0, 300, 1,
                          DualRule::CompressDiff);
    assert!(
        errors.last().unwrap() < &(errors[0] * 1e-8),
        "did not converge: {:?}",
        errors.last()
    );
}

#[test]
fn cecl_converges_across_seeds_and_compressions() {
    for seed in [2, 3, 4] {
        let (net, graph) = network(seed);
        let alpha = net.best_alpha(&graph).expect("non-empty graph");
        let delta = net.delta(alpha, &graph).expect("non-empty graph");
        for k in [0.5, 0.8] {
            if k < tau_threshold(delta) {
                continue;
            }
            let errors = run_cecl(&net, &graph, alpha, 1.0, k, 300, seed,
                                  DualRule::CompressDiff);
            assert!(
                errors.last().unwrap() < &(errors[0] * 1e-3),
                "seed {seed} k {k}: {:?}",
                errors.last()
            );
        }
    }
}

#[test]
fn compression_slows_but_does_not_break() {
    let (net, graph) = network(5);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let rate_at = |k: f64| {
        let e = run_cecl(&net, &graph, alpha, 1.0, k, 200, 5,
                         DualRule::CompressDiff);
        empirical_rate(&e[40..])
    };
    let r1 = rate_at(1.0);
    let r05 = rate_at(0.5);
    assert!(r1 < 1.0 && r05 < 1.0);
    assert!(r1 <= r05 + 0.02, "full {r1} vs half {r05}");
}

#[test]
fn naive_rule_fails_where_cecl_succeeds() {
    // The §3.2 motivation: Eq. (11) stalls at a noise floor, Eq. (13)
    // drives the error to ~0.
    let (net, graph) = network(6);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let diff = run_cecl(&net, &graph, alpha, 1.0, 0.5, 250, 6,
                        DualRule::CompressDiff);
    let naive = run_cecl(&net, &graph, alpha, 1.0, 0.5, 250, 6,
                         DualRule::CompressY);
    assert!(diff.last().unwrap() * 20.0 < *naive.last().unwrap());
}

#[test]
fn works_on_every_paper_topology() {
    let net = QuadraticNetwork::random(8, 12, 24, 0.5, 0.5, 7);
    for graph in [
        Graph::chain(8),
        Graph::ring(8),
        Graph::multiplex_ring(8),
        Graph::complete(8),
    ] {
        let alpha = net.best_alpha(&graph).expect("non-empty graph");
        let errors = run_cecl(&net, &graph, alpha, 1.0, 0.8, 250, 7,
                              DualRule::CompressDiff);
        assert!(
            errors.last().unwrap() < &(errors[0] * 1e-3),
            "topology deg[{:?},{:?}]: final {:?}",
            graph.min_degree(),
            graph.max_degree(),
            errors.last()
        );
    }
}

#[test]
fn delta_and_domain_formulas_consistent() {
    // δ(α*) minimizes the two-branch max; the θ domain at the threshold
    // collapses onto a point near 1... (Lemma 6 arithmetic).
    let (net, graph) = network(8);
    let alpha = net.best_alpha(&graph).expect("non-empty graph");
    let delta = net.delta(alpha, &graph).expect("non-empty graph");
    assert!((0.0..1.0).contains(&delta));
    let thr = tau_threshold(delta);
    // Just above the threshold the domain exists and is tight around 1.
    let (lo, hi) = theta_domain(thr + 1e-6, delta).expect("non-empty");
    assert!(lo < 1.0 + 1e-3 && hi > 1.0 - 1e-3);
    // Far above, it widens.
    let (lo2, hi2) = theta_domain(1.0, delta).unwrap();
    assert!(lo2 <= lo && hi2 >= hi);
    // delta_of is continuous in alpha around alpha*.
    let d1 = delta_of(alpha * 1.001, net.l_smooth, net.mu,
                      graph.max_degree().unwrap() as f64,
                      graph.min_degree().unwrap() as f64);
    assert!((d1 - delta).abs() < 1e-2);
}

#[test]
fn rate_bound_theorem1_structure() {
    // ρ(θ=1, τ=1, δ) = δ (Corollary 1 with θ = 1 — the Peaceman-Rachford
    // point), and ρ grows as √(1−τ) scales the compression penalty.
    for delta in [0.1, 0.5, 0.9] {
        assert!((rate_bound(1.0, 1.0, delta) - delta).abs() < 1e-12);
    }
    let d = 0.4;
    let penalty = |tau: f64| rate_bound(1.0, tau, d) - d;
    assert!(penalty(1.0).abs() < 1e-12);
    let p075 = penalty(0.75);
    let p05 = penalty(0.5);
    // penalty(τ) = √(1−τ)(1 + δ): check exact values.
    assert!((p075 - 0.25f64.sqrt() * (1.0 + d)).abs() < 1e-12);
    assert!((p05 - 0.5f64.sqrt() * (1.0 + d)).abs() < 1e-12);
}

#[test]
fn heterogeneity_hurts_gossip_not_prox() {
    // Convex analogue of the paper's headline: one exact-averaging
    // gossip round cannot reach the global optimum under heterogeneity
    // (consensus of local optima != global optimum), while the
    // primal-dual iteration converges to it exactly.
    let (net, graph) = network(9);
    // "Gossip at convergence": each node at its LOCAL optimum, then
    // repeated MH averaging converges to the mean of local optima.
    let dim = net.dim;
    let mut locals: Vec<Vec<f64>> = net
        .nodes
        .iter()
        .map(|n| {
            cecl::linalg::Cholesky::new(&n.hess).unwrap().solve(&n.btc)
        })
        .collect();
    let w = graph.mh_weights();
    for _ in 0..500 {
        let prev = locals.clone();
        for i in 0..graph.n() {
            let mut acc = vec![0.0; dim];
            for j in 0..graph.n() {
                if w[i][j] != 0.0 {
                    linalg::axpy(w[i][j], &prev[j], &mut acc);
                }
            }
            locals[i] = acc;
        }
    }
    let gossip_err = linalg::norm2(&linalg::sub(&locals[0], &net.w_star));
    let cecl_errors = run_cecl(&net, &graph, net.best_alpha(&graph).expect("non-empty graph"), 1.0,
                               1.0, 300, 9, DualRule::CompressDiff);
    let prox_err = *cecl_errors.last().unwrap();
    assert!(
        prox_err < gossip_err / 100.0,
        "prox {prox_err} vs gossip-mean bias {gossip_err}"
    );
}
