//! Cross-module integration: full (tiny) experiment runs through the
//! coordinator, byte-accounting invariants, and data-pipeline glue.
//! Requires `make artifacts` (self-skips otherwise).

use cecl::algorithms::{AlgorithmSpec, DualPath};
use cecl::coordinator::{run_with_engine, ExperimentSpec};
use cecl::data::Partition;
use cecl::graph::Graph;
use cecl::model::Manifest;
use cecl::runtime::Engine;

fn setup() -> Option<(Engine, Manifest)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some((Engine::cpu().unwrap(), Manifest::load(dir).unwrap()))
}

/// CI-sized spec: 4 nodes, 2 epochs, small data.
fn tiny_spec(alg: AlgorithmSpec) -> ExperimentSpec {
    ExperimentSpec {
        dataset: "fashion".into(),
        algorithm: alg,
        epochs: 2,
        nodes: 4,
        train_per_node: 100,
        test_size: 200,
        local_steps: 2,
        eta: 0.04,
        eval_every: 1,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn every_algorithm_runs_end_to_end() {
    let Some((engine, manifest)) = setup() else { return };
    let graph = Graph::ring(4);
    for alg in [
        AlgorithmSpec::Sgd,
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::CEcl { k_frac: 0.1, theta: 1.0, dense_first_epoch: true },
        AlgorithmSpec::NaiveCEcl { k_frac: 0.1, theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 2 },
    ] {
        let name = alg.name();
        let report =
            run_with_engine(&engine, &manifest, &tiny_spec(alg), &graph)
                .unwrap_or_else(|e| panic!("{name}: {e:?}"));
        assert_eq!(report.history.records.len(), 2, "{name}: eval points");
        assert!(report.final_accuracy > 0.05, "{name}: degenerate accuracy");
        assert!(
            report.history.records[0].train_loss.is_finite(),
            "{name}: train loss"
        );
    }
}

#[test]
fn byte_accounting_matches_analytic_rates() {
    let Some((engine, manifest)) = setup() else { return };
    let graph = Graph::ring(4);
    let ds = manifest.dataset("fashion").unwrap();
    let d = ds.d_pad as f64;
    // 100 samples, batch 50 => 2 batches/epoch; K=2 => 1 round/epoch.
    let rounds_per_epoch = 1.0;
    let epochs = 2.0;
    let neighbors = 2.0;

    // D-PSGD: dense w per neighbor per round.
    let r = run_with_engine(&engine, &manifest, &tiny_spec(AlgorithmSpec::DPsgd),
                            &graph).unwrap();
    let want = rounds_per_epoch * neighbors * d * 4.0;
    assert!(
        (r.mean_bytes_per_epoch - want).abs() < 1.0,
        "dpsgd: {} vs {want}",
        r.mean_bytes_per_epoch
    );

    // ECL: dense y per neighbor per round — identical bytes to D-PSGD.
    let r_ecl = run_with_engine(
        &engine, &manifest, &tiny_spec(AlgorithmSpec::Ecl { theta: 1.0 }),
        &graph,
    ).unwrap();
    assert!((r_ecl.mean_bytes_per_epoch - want).abs() < 1.0);

    // C-ECL (k=10%, no warmup): COO idx+val = 8 bytes per kept coord.
    let mut spec = tiny_spec(AlgorithmSpec::CEcl {
        k_frac: 0.1,
        theta: 1.0,
        dense_first_epoch: false,
    });
    spec.seed = 3;
    let r_cecl = run_with_engine(&engine, &manifest, &spec, &graph).unwrap();
    let want_cecl = rounds_per_epoch * neighbors * d * 0.1 * 8.0;
    let tol = want_cecl * 0.05; // Bernoulli(k) mask size fluctuates
    assert!(
        (r_cecl.mean_bytes_per_epoch - want_cecl).abs() < tol,
        "cecl: {} vs {want_cecl}",
        r_cecl.mean_bytes_per_epoch
    );
    // Ratio ladder: the paper's x(2/k·...) ordering.
    assert!(r_cecl.mean_bytes_per_epoch < r_ecl.mean_bytes_per_epoch / 4.0);

    // Warmup epoch adds one dense epoch's worth.
    let r_warm = run_with_engine(
        &engine,
        &manifest,
        &tiny_spec(AlgorithmSpec::CEcl {
            k_frac: 0.1,
            theta: 1.0,
            dense_first_epoch: true,
        }),
        &graph,
    ).unwrap();
    assert!(
        r_warm.mean_bytes_per_epoch > r_cecl.mean_bytes_per_epoch * 2.0,
        "warmup must cost more: {} vs {}",
        r_warm.mean_bytes_per_epoch,
        r_cecl.mean_bytes_per_epoch
    );
}

#[test]
fn deterministic_given_seed() {
    let Some((engine, manifest)) = setup() else { return };
    let graph = Graph::ring(4);
    let spec = tiny_spec(AlgorithmSpec::CEcl {
        k_frac: 0.2,
        theta: 1.0,
        dense_first_epoch: false,
    });
    let a = run_with_engine(&engine, &manifest, &spec, &graph).unwrap();
    let b = run_with_engine(&engine, &manifest, &spec, &graph).unwrap();
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.total_bytes, b.total_bytes);
    let mut spec2 = spec.clone();
    spec2.seed = 8;
    let c = run_with_engine(&engine, &manifest, &spec2, &graph).unwrap();
    assert_ne!(a.total_bytes, c.total_bytes); // different masks w.h.p.
}

#[test]
fn dual_paths_agree_in_training() {
    // The L1 Pallas kernel through PJRT vs the native twin: identical
    // wire traffic and (numerically) identical learning trajectory.
    let Some((engine, manifest)) = setup() else { return };
    let graph = Graph::ring(4);
    let mut spec = tiny_spec(AlgorithmSpec::CEcl {
        k_frac: 0.2,
        theta: 1.0,
        dense_first_epoch: false,
    });
    spec.dual_path = DualPath::Native;
    let native = run_with_engine(&engine, &manifest, &spec, &graph).unwrap();
    spec.dual_path = DualPath::Pjrt;
    let pjrt = run_with_engine(&engine, &manifest, &spec, &graph).unwrap();
    assert_eq!(native.total_bytes, pjrt.total_bytes, "wire traffic differs");
    assert!(
        (native.final_accuracy - pjrt.final_accuracy).abs() < 2e-2,
        "trajectories diverged: {} vs {}",
        native.final_accuracy,
        pjrt.final_accuracy
    );
}

#[test]
fn heterogeneous_partition_plumbs_through() {
    let Some((engine, manifest)) = setup() else { return };
    let graph = Graph::ring(4);
    let mut spec = tiny_spec(AlgorithmSpec::DPsgd);
    spec.partition = Partition::Heterogeneous { classes_per_node: 8 };
    let report = run_with_engine(&engine, &manifest, &spec, &graph).unwrap();
    assert!(report.partition.contains("heterogeneous"));
    assert!(report.final_accuracy > 0.05);
}

#[test]
fn topologies_change_byte_costs() {
    let Some((engine, manifest)) = setup() else { return };
    let mut costs = Vec::new();
    for (name, graph) in [
        ("chain", Graph::chain(4)),
        ("ring", Graph::ring(4)),
        ("complete", Graph::complete(4)),
    ] {
        let r = run_with_engine(
            &engine, &manifest, &tiny_spec(AlgorithmSpec::DPsgd), &graph,
        ).unwrap();
        costs.push((name, r.mean_bytes_per_epoch));
    }
    // chain (1.5 avg degree) < ring (2) < complete (3).
    assert!(costs[0].1 < costs[1].1);
    assert!(costs[1].1 < costs[2].1);
}

#[test]
fn sgd_uses_all_data_and_sends_nothing() {
    let Some((engine, manifest)) = setup() else { return };
    let graph = Graph::ring(4);
    let r = run_with_engine(&engine, &manifest, &tiny_spec(AlgorithmSpec::Sgd),
                            &graph).unwrap();
    assert_eq!(r.total_bytes, 0);
    assert!(r.final_accuracy > 0.1);
}
