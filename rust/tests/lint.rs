//! Self-tests for the determinism lint (`cecl::analysis`).
//!
//! Three layers: (1) `lint_source` semantics on inline sources — each
//! rule fires in its scope and stays quiet outside it, directives
//! suppress exactly their rule on exactly their line; (2) the seeded
//! fixture trees under `rust/tests/lint_fixtures/` — what the
//! acceptance criterion "exits nonzero on every seeded violation
//! fixture" pins; (3) the real tree — `rust/src` must lint clean,
//! which is what makes the CI gate a no-op until someone regresses an
//! invariant.

use std::path::{Path, PathBuf};

use cecl::analysis::{lint_source, lint_tree, Violation};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/lint_fixtures")
        .join(name)
}

fn rules(vs: &[Violation]) -> Vec<&'static str> {
    let mut r: Vec<&'static str> = vs.iter().map(|v| v.rule).collect();
    r.sort_unstable();
    r.dedup();
    r
}

// -------------------------------------------------------------------
// lint_source semantics
// -------------------------------------------------------------------

#[test]
fn wall_clock_scoped_to_deterministic_modules() {
    let src = "pub fn t() -> std::time::Instant { std::time::Instant::now() }\n";
    // Fires in a deterministic module...
    assert!(!lint_source("sim/engine.rs", src).is_empty());
    assert!(!lint_source("algorithms/cecl.rs", src).is_empty());
    // ...and is legal where wall-clock is the measured quantity.
    assert!(lint_source("net/runtime.rs", src).is_empty());
    assert!(lint_source("coordinator/mod.rs", src).is_empty());
    assert!(lint_source("util/bench.rs", src).is_empty());
}

#[test]
fn banned_tokens_match_whole_words_only() {
    // Idents merely containing a banned token must not fire.
    let src = "pub struct InstantaneousRate;\npub fn x(h: MyHashMapLike) {}\n";
    assert!(lint_source("sim/mod.rs", src).is_empty());
}

#[test]
fn tokens_inside_strings_and_comments_do_not_fire() {
    let src = concat!(
        "// Instant is banned here; HashMap too.\n",
        "pub fn describe() -> &'static str {\n",
        "    \"uses Instant and HashMap and thread_rng\"\n",
        "}\n",
    );
    assert!(lint_source("sim/mod.rs", src).is_empty());
}

#[test]
fn test_modules_are_exempt() {
    let src = concat!(
        "#[cfg(test)]\n",
        "mod tests {\n",
        "    use std::time::Instant;\n",
        "    #[test]\n",
        "    fn timing() { let _ = Instant::now(); }\n",
        "}\n",
    );
    assert!(lint_source("sim/mod.rs", src).is_empty());
}

#[test]
fn panic_rules_scope_to_decode_fns_of_wire_files() {
    let decode = "pub fn decode(b: &[u8]) -> u32 { b.first().copied().unwrap() as u32 }\n";
    let encode = "pub fn encode(b: &[u8]) -> u32 { b.first().copied().unwrap() as u32 }\n";
    // decode-scope fn in a wire file: fires.
    let vs = lint_source("net/wire.rs", decode);
    assert_eq!(rules(&vs), vec!["panic-decode"], "{vs:?}");
    // encode fn in the same file: exempt.
    assert!(lint_source("net/wire.rs", encode).is_empty());
    // decode fn in a non-wire file: exempt.
    assert!(lint_source("sim/mod.rs", decode).is_empty());
}

#[test]
fn indexing_flagged_but_not_attributes_or_macros() {
    let src = concat!(
        "#[derive(Debug)]\n",
        "pub struct P;\n",
        "pub fn parse(b: &[u8]) -> Vec<u8> {\n",
        "    let v = vec![0u8; 4];\n",
        "    let _ = v;\n",
        "    b.to_vec()\n",
        "}\n",
    );
    assert!(lint_source("net/wire.rs", src).is_empty());
    let bad = "pub fn parse(b: &[u8]) -> u8 { b[0] }\n";
    let vs = lint_source("net/wire.rs", bad);
    assert_eq!(rules(&vs), vec!["index-decode"], "{vs:?}");
}

#[test]
fn panic_macros_fire_but_debug_assert_does_not() {
    let bang = "pub fn decode(n: usize) { assert!(n > 0); }\n";
    let vs = lint_source("compress/codec.rs", bang);
    assert_eq!(rules(&vs), vec!["panic-decode"], "{vs:?}");
    let dbg = "pub fn decode(n: usize) { debug_assert!(n > 0); }\n";
    assert!(lint_source("compress/codec.rs", dbg).is_empty());
}

#[test]
fn decode_alloc_scoped_to_decode_into_of_wire_files() {
    let bad = "pub fn decode_into(out: &mut Vec<u8>) { let v = Vec::new(); out.extend(v); }\n";
    let vs = lint_source("compress/codec.rs", bad);
    assert_eq!(rules(&vs), vec!["decode-alloc"], "{vs:?}");
    // The allocating `decode` path is the legal place to allocate.
    let decode = "pub fn decode(n: usize) -> Vec<f32> { vec![0.0; n] }\n";
    assert!(lint_source("compress/codec.rs", decode).is_empty());
    // decode_into outside the wire files is exempt.
    assert!(lint_source("sim/mod.rs", bad).is_empty());
    // A justified allow works like every other rule's.
    let allowed = concat!(
        "pub fn decode_into(out: &mut Vec<u8>) {\n",
        "    // det:allow(decode-alloc): lazy one-time init, not steady state\n",
        "    let v = Vec::new();\n",
        "    out.extend(v);\n",
        "}\n",
    );
    assert!(lint_source("compress/codec.rs", allowed).is_empty());
}

#[test]
fn trailing_directive_suppresses_same_line() {
    let src = concat!(
        "pub fn decode(b: &[u8]) -> u8 {\n",
        "    b[0] // det:allow(index-decode): length checked by caller\n",
        "}\n",
    );
    assert!(lint_source("net/wire.rs", src).is_empty());
}

#[test]
fn standalone_directive_targets_next_code_line_only() {
    let src = concat!(
        "pub fn decode(b: &[u8]) -> u8 {\n",
        "    // det:allow(index-decode): first byte only, len pre-checked\n",
        "    let hi = b[0];\n",
        "    let lo = b[1];\n",
        "    hi.wrapping_add(lo)\n",
        "}\n",
    );
    let vs = lint_source("net/wire.rs", src);
    // The directive covers line 3; line 4 still fires.
    assert_eq!(vs.len(), 1, "{vs:?}");
    assert_eq!(vs[0].rule, "index-decode");
    assert_eq!(vs[0].line, 4);
}

#[test]
fn directive_suppresses_only_named_rules() {
    let src = concat!(
        "pub fn decode(b: &[u8]) -> u8 {\n",
        "    // det:allow(panic-decode): unwrap is on a checked branch\n",
        "    b[0].checked_add(1).unwrap()\n",
        "}\n",
    );
    let vs = lint_source("net/wire.rs", src);
    // panic-decode suppressed; the indexing on the same line is not.
    assert_eq!(rules(&vs), vec!["index-decode"], "{vs:?}");
}

#[test]
fn directive_without_justification_is_a_violation_and_inert() {
    let src = concat!(
        "pub fn step() {\n",
        "    // det:allow(wall-clock)\n",
        "    let _ = std::time::Instant::now();\n",
        "}\n",
    );
    let vs = lint_source("sim/mod.rs", src);
    assert_eq!(rules(&vs), vec!["allow-justification", "wall-clock"],
               "{vs:?}");
}

#[test]
fn directive_with_unknown_rule_is_a_violation_and_inert() {
    let src = concat!(
        "pub fn step() {\n",
        "    // det:allow(wallclock): misspelled\n",
        "    let _ = std::time::Instant::now();\n",
        "}\n",
    );
    let vs = lint_source("graph/mod.rs", src);
    assert_eq!(rules(&vs), vec!["allow-justification", "wall-clock"],
               "{vs:?}");
    assert!(vs.iter().any(|v| v.message.contains("unknown rule")),
            "{vs:?}");
}

#[test]
fn multi_rule_directive_suppresses_both() {
    let src = concat!(
        "pub fn decode(b: &[u8]) -> u8 {\n",
        "    // det:allow(index-decode, panic-decode): len pre-checked\n",
        "    b[0].checked_add(1).unwrap()\n",
        "}\n",
    );
    assert!(lint_source("net/wire.rs", src).is_empty());
}

#[test]
fn violation_display_is_file_line_rule() {
    let vs = lint_source("sim/mod.rs",
                         "pub fn t() { let _ = Instant::now(); }\n");
    assert_eq!(vs.len(), 1);
    let line = vs[0].to_string();
    assert!(line.starts_with("sim/mod.rs:1: [wall-clock]"), "{line}");
}

// -------------------------------------------------------------------
// Seeded fixture trees (the CI acceptance surface)
// -------------------------------------------------------------------

#[test]
fn fixture_wallclock_in_sim_fires() {
    let vs = lint_tree(&fixture("wallclock_in_sim")).unwrap();
    assert!(!vs.is_empty());
    assert!(vs.iter().all(|v| v.rule == "wall-clock"), "{vs:?}");
    assert!(vs.iter().all(|v| v.file == "sim/mod.rs"), "{vs:?}");
}

#[test]
fn fixture_hashmap_in_algorithms_fires() {
    let vs = lint_tree(&fixture("hashmap_in_algorithms")).unwrap();
    assert!(!vs.is_empty());
    assert!(vs.iter().all(|v| v.rule == "unordered-container"), "{vs:?}");
}

#[test]
fn fixture_rng_in_compress_fires() {
    let vs = lint_tree(&fixture("rng_in_compress")).unwrap();
    assert_eq!(rules(&vs), vec!["ambient-rng"], "{vs:?}");
}

#[test]
fn fixture_unwrap_in_decode_fires_both_rules() {
    let vs = lint_tree(&fixture("unwrap_in_decode")).unwrap();
    assert_eq!(rules(&vs), vec!["index-decode", "panic-decode"], "{vs:?}");
}

#[test]
fn fixture_missing_justification_fires() {
    let vs = lint_tree(&fixture("missing_justification")).unwrap();
    assert_eq!(rules(&vs), vec!["allow-justification", "wall-clock"],
               "{vs:?}");
}

#[test]
fn fixture_unknown_rule_fires() {
    let vs = lint_tree(&fixture("unknown_rule")).unwrap();
    assert_eq!(rules(&vs), vec!["allow-justification", "wall-clock"],
               "{vs:?}");
}

#[test]
fn fixture_decode_alloc_in_wire_fires() {
    let vs = lint_tree(&fixture("decode_alloc_in_wire")).unwrap();
    assert_eq!(rules(&vs), vec!["decode-alloc"], "{vs:?}");
    // One hit per banned constructor: to_vec, Vec::new,
    // Vec::with_capacity, vec!, collect.
    assert_eq!(vs.len(), 5, "{vs:?}");
    assert!(vs.iter().all(|v| v.file == "compress/codec.rs"), "{vs:?}");
}

#[test]
fn fixture_allowed_clean_is_clean() {
    let vs = lint_tree(&fixture("allowed_clean")).unwrap();
    assert!(vs.is_empty(), "allow-list failed to suppress: {vs:?}");
}

// -------------------------------------------------------------------
// The real tree
// -------------------------------------------------------------------

#[test]
fn real_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let vs = lint_tree(&root).unwrap();
    let listing: Vec<String> = vs.iter().map(|v| v.to_string()).collect();
    assert!(
        vs.is_empty(),
        "rust/src must lint clean; fix or add a justified allow:\n{}",
        listing.join("\n"),
    );
}
