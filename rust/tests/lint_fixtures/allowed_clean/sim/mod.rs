// Lint fixture (never compiled): a properly justified allow suppresses
// exactly its rule on its target line — this tree must lint clean.
pub fn trace_stamp() -> u64 {
    // det:allow(wall-clock): fixture exercises suppression; this is a
    // lint self-test source, not a runtime path.
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
