//! Seeded violation tree: every banned allocation constructor inside a
//! `decode_into` implementation of a wire file.  The `decode-alloc`
//! rule must flag each one; the allocating `decode` twin below stays
//! legal.

pub fn decode_into(b: &[f32], out: &mut [f32]) -> Result<(), ()> {
    let staged = b.to_vec();
    let mut spill = Vec::new();
    spill.extend_from_slice(&staged);
    let mut lut = Vec::with_capacity(out.len());
    lut.extend_from_slice(&spill);
    let zeros = vec![0.0f32; out.len()];
    let summed: Vec<f32> =
        zeros.iter().zip(&lut).map(|(x, y)| x + y).collect();
    for (o, v) in out.iter_mut().zip(&summed) {
        *o = *v;
    }
    Ok(())
}

pub fn decode(n: usize) -> Vec<f32> {
    vec![0.0f32; n]
}
