// Lint fixture (never compiled): an unordered container in protocol
// state must trip the unordered-container rule.
use std::collections::HashMap;

pub struct Node {
    pub duals: HashMap<usize, Vec<f32>>,
}
