// Lint fixture (never compiled): a directive with no justification is
// itself a violation and suppresses nothing.
pub fn now_ns() -> u64 {
    // det:allow(wall-clock)
    let t = std::time::Instant::now();
    t.elapsed().as_nanos() as u64
}
