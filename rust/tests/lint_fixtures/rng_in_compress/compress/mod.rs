// Lint fixture (never compiled): ambient randomness in a codec path
// must trip the ambient-rng rule.
pub fn sample_mask(dim: usize) -> Vec<u32> {
    let mut rng = rand::thread_rng();
    let _ = &mut rng;
    (0..dim as u32).collect()
}
