// Lint fixture (never compiled): a directive naming an unknown rule is
// itself a violation and suppresses nothing.
pub fn neighbors() -> Vec<usize> {
    // det:allow(wallclock): misspelled rule name, should not suppress
    let t = std::time::Instant::now();
    let _ = t;
    Vec::new()
}
