// Lint fixture (never compiled): a panic and a direct index on peer
// bytes in the parse path must trip panic-decode and index-decode.
pub fn read_message(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[0..4].try_into().unwrap())
}
