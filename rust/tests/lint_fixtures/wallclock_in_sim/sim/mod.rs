// Lint fixture (never compiled): a wall-clock read inside the
// virtual-time engine must trip the wall-clock rule.
use std::time::Instant;

pub fn step() -> u64 {
    let t0 = Instant::now();
    t0.elapsed().as_nanos() as u64
}
