//! Socket-engine test suite — loopback TCP only, artifact-free:
//!
//! * cross-engine byte identity: an 8-node loopback deployment reports
//!   exactly the per-directed-edge payload bytes the virtual-time
//!   engine predicts for the same spec and seed, for the codec ladder
//!   {identity, rand_k:0.1, ef+top_k:0.1}, under sync *and* `async:2`
//!   rounds (frame sizes are data-independent for these codecs, so real
//!   arrival timing cannot change byte counts);
//! * sync trajectory identity: same seed ⇒ bit-identical final accuracy
//!   across engines (machines fold per-neighbor slots in fixed order);
//! * header/payload split: wire framing overhead is metered apart from
//!   payload bytes, and the in-process engines report zero overhead;
//! * churn lifecycle: killing one node mid-run (sockets slammed shut,
//!   no `Bye`) tears down exactly its edges on the survivors, which
//!   finish every remaining round;
//! * the acceptance run: a 64-node loopback deployment completes and
//!   matches the sim's byte prediction.

use cecl::algorithms::{AlgorithmSpec, RoundPolicy};
use cecl::compress::CodecSpec;
use cecl::coordinator::{run_simulated_native, ExecMode, ExperimentSpec,
                        Report};
use cecl::graph::Graph;
use cecl::net::{run_net_native, NetConfig};
use cecl::sim::SimConfig;

fn spec(nodes: usize, epochs: usize, codec: &str,
        rounds: RoundPolicy) -> ExperimentSpec {
    ExperimentSpec {
        dataset: "tiny".to_string(),
        algorithm: AlgorithmSpec::CEclCodec {
            codec: CodecSpec::parse(codec).unwrap(),
            theta: 1.0,
            dense_first_epoch: false,
        },
        epochs,
        nodes,
        train_per_node: 20,
        test_size: 40,
        local_steps: 2,
        eta: 0.1,
        eval_every: 1,
        seed: 42,
        exec: ExecMode::Simulated(SimConfig::default()),
        rounds,
        ..ExperimentSpec::default()
    }
}

/// Run the same spec through both engines and pin the byte accounting
/// against each other.  Returns `(net, sim)` for extra assertions.
fn assert_bytes_match(s: &ExperimentSpec, graph: &Graph) -> (Report, Report) {
    let predicted = run_simulated_native(s, graph).unwrap();
    let net = run_net_native(s, graph, &NetConfig::default()).unwrap();
    assert!(
        !net.edge_payload_bytes.is_empty(),
        "net run must report per-edge payload bytes"
    );
    assert_eq!(
        net.edge_payload_bytes, predicted.edge_payload_bytes,
        "per-directed-edge payload bytes diverge from the sim prediction \
         ({} rounds {})",
        s.algorithm.name(),
        s.rounds.name()
    );
    assert_eq!(net.total_bytes, predicted.total_bytes);
    // The split satellite: headers are extra and engine-specific; the
    // payload quantity stays engine-comparable.
    assert_eq!(predicted.header_overhead_bytes, 0);
    assert!(
        net.header_overhead_bytes > 0,
        "a real wire has framing overhead"
    );
    (net, predicted)
}

#[test]
fn sync_loopback_bytes_and_trajectory_match_sim() {
    let graph = Graph::ring(8);
    for codec in ["identity", "rand_k:0.1", "ef+top_k:0.1"] {
        let s = spec(8, 2, codec, RoundPolicy::Sync);
        let (net, predicted) = assert_bytes_match(&s, &graph);
        // Sync is a barrier schedule: the trajectory itself is engine-
        // independent, down to the bit.
        assert_eq!(
            net.final_accuracy.to_bits(),
            predicted.final_accuracy.to_bits(),
            "sync trajectory diverged for {codec}"
        );
        assert_eq!(net.max_staleness, 0);
        assert_eq!(net.edges_churned, 0);
        assert_eq!(net.frames_dropped_by_churn, 0);
        assert_eq!(net.history.records.len(), 2);
    }
}

#[test]
fn async_loopback_bytes_match_sim_with_bounded_staleness() {
    let graph = Graph::ring(8);
    for codec in ["identity", "rand_k:0.1", "ef+top_k:0.1"] {
        let s = spec(8, 2, codec, RoundPolicy::Async { max_staleness: 2 });
        let (net, _) = assert_bytes_match(&s, &graph);
        // Real arrivals decide staleness, but the in-protocol bound
        // still holds and is reported.
        assert!(
            net.max_staleness <= 2,
            "staleness bound violated: {} for {codec}",
            net.max_staleness
        );
        assert_eq!(net.history.records.len(), 2);
    }
}

#[test]
fn killed_node_maps_onto_churn_lifecycle_and_survivors_finish() {
    let graph = Graph::ring(8);
    let s = spec(8, 2, "identity", RoundPolicy::Sync);
    // 2 epochs x 1 round/epoch; node 3 slams its sockets shut (no Bye)
    // right after round 0 — before it even evaluates.
    let net = NetConfig { kill: Some((3, 0)), ..NetConfig::default() };
    let report = run_net_native(&s, &graph, &net).unwrap();
    // Ring: node 3 touches exactly 2 edges, each torn down once by its
    // surviving endpoint.
    assert_eq!(
        report.edges_churned, 2,
        "peer loss must map onto the churn teardown lifecycle"
    );
    // The surviving 7 nodes complete every remaining round and both
    // eval boundaries (epoch 1 means over 7 reporters).
    assert_eq!(report.history.records.len(), 2);
    assert!(report.final_accuracy.is_finite());
    assert!(report.total_bytes > 0);
}

#[test]
fn acceptance_64_node_deployment_matches_sim_prediction() {
    let graph = Graph::ring(64);
    let s = spec(64, 1, "rand_k:0.1", RoundPolicy::Sync);
    let (net, _) = assert_bytes_match(&s, &graph);
    // 64 nodes x 2 directed slots per ring edge.
    assert_eq!(net.edge_payload_bytes.len(), 128);
    assert!(net.edge_payload_bytes.iter().all(|&b| b > 0));
}
