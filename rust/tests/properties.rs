//! Property-based suites over the L3 substrates (util::prop — the
//! in-repo proptest substitute; each property runs across seeded random
//! inputs with ramping sizes).

use std::sync::Arc;

use cecl::algorithms::{build_machine, AlgorithmSpec, BuildCtx, CEclNode,
                       ChocoNode, DualPath, DualRule, LeadNode,
                       NodeAlgorithm, NodeStateMachine, RoundPolicy};
use cecl::comm::{build_bus, Msg, Outbox};
use cecl::compress::{measure_codec_contraction, CodecSpec, CooVec, EdgeCtx,
                     RandK, WireMode};
use cecl::data::{build_node_datasets, dirichlet_class_counts, label_skew,
                 node_classes, Partition, SyntheticSpec};
use cecl::graph::{Graph, TopologyView};
use cecl::linalg::{Cholesky, Mat};
use cecl::model::DatasetManifest;
use cecl::prop_assert;
use cecl::quadratic::{rate_bound, tau_threshold, theta_domain};
use cecl::runtime::native;
use cecl::util::prop::{check, Ctx};
use cecl::util::rng::{streams, Pcg};

// ---------------------------------------------------------------------
// Compression operators (Assumption 1)
// ---------------------------------------------------------------------

#[test]
fn prop_randk_linearity_eq8_eq9() {
    // comp(x+y; ω) = comp(x; ω) + comp(y; ω) and comp(−x; ω) = −comp(x; ω)
    // hold EXACTLY for fixed ω.
    check("randk-linearity", 40, 4096, |ctx: &mut Ctx| {
        let d = ctx.size.max(4);
        let x = ctx.vec_f32(d);
        let y = ctx.vec_f32(d);
        let k = 0.05 + 0.9 * ctx.rng.f64();
        let op = RandK::new(k);
        let mask = op.sample_mask(d, &mut ctx.rng);
        let sum: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        let neg: Vec<f32> = x.iter().map(|a| -a).collect();
        let cx = CooVec::gather(&x, &mask);
        let cy = CooVec::gather(&y, &mask);
        let cs = CooVec::gather(&sum, &mask);
        let cn = CooVec::gather(&neg, &mask);
        for i in 0..mask.len() {
            prop_assert!(
                cs.val[i] == cx.val[i] + cy.val[i],
                "Eq.8 violated at {i}"
            );
            prop_assert!(cn.val[i] == -cx.val[i], "Eq.9 violated at {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_randk_codec_contraction_eq7() {
    // E‖comp(x) − x‖² ≤ (1 − τ)‖x‖² within sampling error — measured
    // through real encode→decode round trips on both wire modes.
    check("randk-eq7", 10, 2000, |ctx: &mut Ctx| {
        let d = ctx.size.max(256);
        let x = ctx.vec_f32(d);
        let k = 0.1 + 0.8 * ctx.rng.f64();
        let seed = ctx.rng.next_u64();
        for mode in [WireMode::Explicit, WireMode::ValuesOnly] {
            let spec = CodecSpec::RandK { k_frac: k, mode };
            let measured = measure_codec_contraction(&spec, &x, 40, seed);
            let want = 1.0 - spec.tau(d);
            prop_assert!(
                (measured - want).abs() < 0.15,
                "contraction {measured} vs 1-tau {want} (k={k})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_topk_codec_never_worse_than_randk_energy() {
    check("topk-energy", 20, 2048, |ctx: &mut Ctx| {
        let d = ctx.size.max(64);
        let x = ctx.vec_f32(d);
        let k = 0.05 + 0.4 * ctx.rng.f64();
        let seed = ctx.rng.next_u64();
        // Decoded energy = ‖comp(x)‖²; top-k keeps the largest coords.
        let e = |spec: &CodecSpec| -> f64 {
            let mut codec = spec.build();
            let ec = EdgeCtx {
                seed,
                edge: 0,
                round: 0,
                receiver: 1,
                dim: d,
                epoch: 0,
            };
            let f = codec.encode(&x, &ec);
            codec
                .decode(&f, &ec)
                .unwrap()
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum()
        };
        let top = e(&CodecSpec::TopK { k_frac: k });
        let rand = e(&CodecSpec::RandK { k_frac: k, mode: WireMode::Explicit });
        prop_assert!(top >= rand - 1e-9, "top-k kept less energy");
        Ok(())
    });
}

#[test]
fn prop_identity_codec_roundtrip_bit_exact() {
    check("identity", 10, 512, |ctx: &mut Ctx| {
        let d = ctx.size.max(1);
        let x = ctx.vec_f32(d);
        let mut codec = CodecSpec::Identity.build();
        let ec = EdgeCtx {
            seed: ctx.rng.next_u64(),
            edge: 0,
            round: 0,
            receiver: 0,
            dim: d,
            epoch: 0,
        };
        let f = codec.encode(&x, &ec);
        prop_assert!(f.wire_bytes() == 4 * d, "dense byte accounting");
        let y = codec.decode(&f, &ec).map_err(|e| e.to_string())?;
        prop_assert!(y == x, "identity not exact");
        Ok(())
    });
}

#[test]
fn prop_coo_scatter_gather_roundtrip() {
    check("coo-roundtrip", 30, 2048, |ctx: &mut Ctx| {
        let d = ctx.size.max(8);
        let x = ctx.vec_f32(d);
        let mask = RandK::new(0.3).sample_mask(d, &mut ctx.rng);
        let coo = CooVec::gather(&x, &mask);
        let dense = coo.to_dense();
        for (i, &v) in dense.iter().enumerate() {
            let expect = if mask.contains(&(i as u32)) { x[i] } else { 0.0 };
            prop_assert!(v == expect, "coord {i}");
        }
        prop_assert!(coo.wire_bytes() == 8 * mask.len(), "byte accounting");
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Fused dual update (native twin of the L1 kernel)
// ---------------------------------------------------------------------

#[test]
fn prop_dual_update_fixed_point() {
    // At a fixed point (y_recv == z) the update must leave z unchanged
    // for every mask and θ.
    check("dual-fixed-point", 30, 1024, |ctx: &mut Ctx| {
        let d = ctx.size.max(16);
        let mut z = ctx.vec_f32(d);
        let z0 = z.clone();
        let w = ctx.vec_f32(d);
        let theta = ctx.rng.f32();
        let mask = RandK::new(0.4).sample_mask(d, &mut ctx.rng);
        let ycomp = CooVec::gather(&z0, &mask); // comp(y) with y == z
        let mut yvals = Vec::new();
        native::dual_update_sparse(&mut z, &w, &ycomp, &mask, theta, 0.7,
                                   &mut yvals);
        for i in 0..d {
            prop_assert!((z[i] - z0[i]).abs() < 1e-6, "z moved at {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_dual_update_dense_sparse_agree() {
    check("dual-dense-sparse", 25, 1024, |ctx: &mut Ctx| {
        let d = ctx.size.max(16);
        let z0 = ctx.vec_f32(d);
        let w = ctx.vec_f32(d);
        let y = ctx.vec_f32(d);
        let theta = ctx.rng.f32();
        let taa = ctx.rng.normal_f32();
        let mask_in = RandK::new(0.3).sample_mask(d, &mut ctx.rng);
        let mask_out = RandK::new(0.3).sample_mask(d, &mut ctx.rng);
        // Dense path.
        let mut mi = Vec::new();
        let mut mo = Vec::new();
        RandK::mask_to_dense(d, &mask_in, &mut mi);
        RandK::mask_to_dense(d, &mask_out, &mut mo);
        let ycomp_dense: Vec<f32> =
            y.iter().zip(&mi).map(|(a, b)| a * b).collect();
        let mut zn = vec![0.0; d];
        let mut ys = vec![0.0; d];
        native::dual_update_into(&z0, &w, &ycomp_dense, &mi, &mo, theta, taa,
                                 &mut zn, &mut ys);
        // Sparse path.
        let mut z_sp = z0.clone();
        let coo = CooVec::gather(&y, &mask_in);
        let mut yvals = Vec::new();
        native::dual_update_sparse(&mut z_sp, &w, &coo, &mask_out, theta, taa,
                                   &mut yvals);
        for i in 0..d {
            prop_assert!((z_sp[i] - zn[i]).abs() < 1e-5, "z mismatch at {i}");
        }
        for (k, &i) in mask_out.iter().enumerate() {
            prop_assert!(
                (yvals[k] - ys[i as usize]).abs() < 1e-5,
                "y mismatch at {i}"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// The poll-driven (round_begin / on_message / round_end) protocol path
// ---------------------------------------------------------------------

fn sm_manifest(input: (usize, usize, usize), classes: usize)
               -> DatasetManifest {
    DatasetManifest::synthetic_linear("p", input, classes, 2, 2)
}

fn sm_ctx(node: usize, graph: &Arc<Graph>, seed: u64,
          manifest: DatasetManifest) -> BuildCtx {
    BuildCtx {
        node,
        graph: Arc::clone(graph),
        manifest,
        seed,
        eta: 0.05,
        local_steps: 2,
        rounds_per_epoch: 4,
        dual_path: DualPath::Native,
        runtime: None,
        round_policy: RoundPolicy::Sync,
    }
}

/// Drive one exchange round of every node by hand (single-threaded)
/// under the given topology view, delivering to each receiver in
/// ascending sender order — the same order the blocking driver drains
/// its neighbors in.  Returns total wire bytes.
fn drive_round_view(nodes: &mut [CEclNode], ws: &mut [Vec<f32>],
                    round: usize, view: &TopologyView) -> usize {
    let n = nodes.len();
    let mut queued: Vec<Vec<(usize, Msg)>> = Vec::with_capacity(n);
    for i in 0..n {
        let mut out = Outbox::new();
        NodeStateMachine::round_begin(&mut nodes[i], round, view, &mut ws[i],
                                      &mut out)
            .unwrap();
        queued.push(out.drain().collect());
    }
    let mut bytes = 0;
    for (src, msgs) in queued.into_iter().enumerate() {
        for (to, msg) in msgs {
            bytes += msg.wire_bytes();
            let mut out = Outbox::new();
            NodeStateMachine::on_message(&mut nodes[to], round, src, msg,
                                         view, &mut ws[to], &mut out)
                .unwrap();
            assert!(out.is_empty(), "C-ECL is single-phase");
        }
    }
    for i in 0..n {
        assert!(nodes[i].round_complete());
        NodeStateMachine::round_end(&mut nodes[i], round, view, &mut ws[i])
            .unwrap();
    }
    bytes
}

/// [`drive_round_view`] over the static full view.
fn drive_round(nodes: &mut [CEclNode], ws: &mut [Vec<f32>],
               round: usize) -> usize {
    let edge_count = match nodes.len() {
        0 => 0,
        n => {
            // All property graphs here are chains/rings over all nodes.
            // Edge counts only size the view; use a safe upper bound.
            n * n
        }
    };
    let view = TopologyView::full(edge_count);
    drive_round_view(nodes, ws, round, &view)
}

/// [`drive_round_view`] over boxed machines — the rival algorithms
/// (CHOCO-SGD, LEAD) drive through the same single-phase schedule.
fn drive_round_dyn(nodes: &mut [Box<dyn NodeStateMachine>],
                   ws: &mut [Vec<f32>], round: usize,
                   view: &TopologyView) {
    let n = nodes.len();
    let mut queued: Vec<Vec<(usize, Msg)>> = Vec::with_capacity(n);
    for i in 0..n {
        let mut out = Outbox::new();
        nodes[i].round_begin(round, view, &mut ws[i], &mut out).unwrap();
        queued.push(out.drain().collect());
    }
    for (src, msgs) in queued.into_iter().enumerate() {
        for (to, msg) in msgs {
            let mut out = Outbox::new();
            nodes[to]
                .on_message(round, src, msg, view, &mut ws[to], &mut out)
                .unwrap();
            assert!(out.is_empty(), "rival machines are single-phase");
        }
    }
    for i in 0..n {
        assert!(nodes[i].round_complete());
        nodes[i].round_end(round, view, &mut ws[i]).unwrap();
    }
}

#[test]
fn prop_state_machine_matches_blocking_exchange() {
    // The two driving modes of the same protocol must produce
    // bit-identical dual state, zsum, and wire bytes after several
    // rounds — for compressed, dense, and naive-rule variants alike.
    check("sm-vs-blocking", 12, 1, |ctx: &mut Ctx| {
        let seed = ctx.rng.next_u64();
        let k = 0.15 + 0.8 * ctx.rng.f64();
        let theta = 0.3 + 0.7 * ctx.rng.f32();
        let rule = if ctx.rng.bernoulli(0.25) {
            DualRule::CompressY
        } else {
            DualRule::CompressDiff
        };
        let rounds = 3usize;
        let graph = Arc::new(Graph::ring(3));
        let manifest = sm_manifest((2, 2, 1), 3); // d = 15
        let d = manifest.d_pad;
        let make_nodes = || -> Vec<CEclNode> {
            (0..3)
                .map(|i| {
                    CEclNode::new(
                        &sm_ctx(i, &graph, seed, manifest.clone()),
                        CodecSpec::RandK { k_frac: k, mode: WireMode::Explicit },
                        theta,
                        0,
                        rule,
                    )
                    .unwrap()
                })
                .collect()
        };
        let make_ws = || -> Vec<Vec<f32>> {
            (0..3u64)
                .map(|i| {
                    let mut rng = Pcg::derive(seed, &[7777, i]);
                    (0..d).map(|_| rng.normal_f32()).collect()
                })
                .collect()
        };

        // Blocking (threaded) reference.
        let mut threaded = make_nodes();
        let (comms, meter) = build_bus(&graph);
        std::thread::scope(|s| {
            let handles: Vec<_> = threaded
                .iter_mut()
                .zip(comms)
                .zip(make_ws())
                .map(|((node, comm), mut w)| {
                    s.spawn(move || {
                        for round in 0..rounds {
                            node.exchange(round, &mut w, &comm).unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });

        // Poll-driven form, driven by hand.
        let mut polled = make_nodes();
        let mut ws = make_ws();
        let mut bytes = 0usize;
        for round in 0..rounds {
            bytes += drive_round(&mut polled, &mut ws, round);
        }

        prop_assert!(
            bytes as u64 == meter.total_bytes(),
            "wire bytes: polled {bytes} vs threaded {}",
            meter.total_bytes()
        );
        for i in 0..3 {
            prop_assert!(
                threaded[i].dual_state() == polled[i].dual_state(),
                "node {i}: dual state diverged (k={k}, theta={theta}, \
                 rule={rule:?})"
            );
            let zt = NodeAlgorithm::zsum(&threaded[i]).unwrap();
            let zp = NodeAlgorithm::zsum(&polled[i]).unwrap();
            prop_assert!(zt == zp, "node {i}: zsum diverged");
        }
        Ok(())
    });
}

#[test]
fn prop_dual_update_dense_sparse_agree_state_machine() {
    // The wire-level form of `prop_dual_update_dense_sparse_agree`:
    // through round_begin, the frame a node emits must decode to the
    // shared-seed mask gather of the dense y = z − 2αa·w (Eqs. 8–9
    // linearity at the wire), and through on_message the z update must
    // equal the fused native::dual_update_sparse kernel.
    check("sm-dual-wire", 15, 1, |ctx: &mut Ctx| {
        let seed = ctx.rng.next_u64();
        let k = 0.2 + 0.6 * ctx.rng.f64();
        let theta = 0.4 + 0.6 * ctx.rng.f32();
        let graph = Arc::new(Graph::chain(2));
        let manifest = sm_manifest((3, 3, 1), 4); // d = 40
        let d = manifest.d_pad;
        let spec = CodecSpec::RandK { k_frac: k, mode: WireMode::Explicit };
        let mut nodes: Vec<CEclNode> = (0..2)
            .map(|i| {
                CEclNode::new(
                    &sm_ctx(i, &graph, seed, manifest.clone()),
                    spec.clone(),
                    theta,
                    0,
                    DualRule::CompressDiff,
                )
                .unwrap()
            })
            .collect();
        let mut ws: Vec<Vec<f32>> = (0..2u64)
            .map(|i| {
                let mut rng = Pcg::derive(seed, &[8888, i]);
                (0..d).map(|_| rng.normal_f32()).collect()
            })
            .collect();
        // Round 0 makes z nonzero; round 1 is the round under test.
        drive_round(&mut nodes, &mut ws, 0);
        let round = 1usize;
        let z_before: Vec<Vec<Vec<f32>>> =
            nodes.iter().map(|n| n.dual_state().clone().into_vecs()).collect();

        // Collect round_begin output per node.
        let view = TopologyView::full(graph.edges().len());
        let mut sent: Vec<cecl::compress::Frame> = Vec::new();
        for i in 0..2 {
            let mut out = Outbox::new();
            NodeStateMachine::round_begin(&mut nodes[i], round, &view,
                                          &mut ws[i], &mut out)
                .unwrap();
            let msgs: Vec<(usize, Msg)> = out.drain().collect();
            prop_assert!(msgs.len() == 1, "node {i}: one neighbor");
            let (to, msg) = msgs.into_iter().next().unwrap();
            prop_assert!(to == 1 - i, "node {i}: wrong dest");
            sent.push(msg.into_frame().unwrap());
        }

        let op = RandK::new(k);
        let mut payloads: Vec<CooVec> = Vec::new(); // decoded wire content
        for i in 0..2usize {
            let to = 1 - i;
            // (a) the mask is the shared-seed ω for (edge 0, round,
            // receiver=to) — never transmitted, re-derived here; the
            // explicit frame must be exactly 8 bytes per kept coord.
            let mut rng = Pcg::derive(
                seed,
                &[streams::EDGE_MASK, 0, round as u64, to as u64],
            );
            let expect_mask = op.sample_mask(d, &mut rng);
            prop_assert!(
                sent[i].wire_bytes() == 8 * expect_mask.len(),
                "node {i}: wire bytes {} != 8·|ω|",
                sent[i].wire_bytes()
            );
            let mut codec = spec.build();
            let ec = EdgeCtx {
                seed,
                edge: 0,
                round,
                receiver: to,
                dim: d,
                epoch: 0,
            };
            let y_wire = codec.decode(&sent[i], &ec).unwrap();
            // (b) decoded values equal the gather of the dense y
            // (Eq. 8/9: comp is exactly linear for fixed ω).
            let sign = graph.edge_sign(i, to);
            let taa = 2.0 * nodes[i].alpha() * sign;
            let y_dense: Vec<f32> = z_before[i][0]
                .iter()
                .zip(&ws[i])
                .map(|(&zv, &wv)| zv - taa * wv)
                .collect();
            let expect_vals = CooVec::gather(&y_dense, &expect_mask);
            for (pos, &idx) in expect_mask.iter().enumerate() {
                prop_assert!(
                    y_wire[idx as usize] == expect_vals.val[pos],
                    "node {i}: wire value at {idx} != dense-y gather"
                );
            }
            payloads.push(CooVec {
                dim: d,
                idx: expect_mask,
                val: expect_vals.val,
            });
        }

        // (c) receiving through on_message equals the fused sparse
        // kernel applied to the pre-round state.
        for i in 0..2usize {
            let from = 1 - i;
            let mut out = Outbox::new();
            NodeStateMachine::on_message(
                &mut nodes[i],
                round,
                from,
                Msg::Frame(sent[from].clone()),
                &view,
                &mut ws[i],
                &mut out,
            )
            .unwrap();
            NodeStateMachine::round_end(&mut nodes[i], round, &view,
                                        &mut ws[i])
                .unwrap();
            let mut z_expect = z_before[i][0].clone();
            let mut yvals = Vec::new();
            native::dual_update_sparse(
                &mut z_expect,
                &ws[i],
                &payloads[from],
                &[],
                theta,
                0.0,
                &mut yvals,
            );
            prop_assert!(
                nodes[i].dual_state().row(0) == z_expect.as_slice(),
                "node {i}: on_message != dual_update_sparse"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_wire_contraction_eq7_state_machine() {
    // Eq. (7) measured on actual wire traffic: the energy a C-ECL node
    // ships per round is a τ = k fraction of the dense y's energy, in
    // expectation over the shared-seed masks.
    check("sm-wire-eq7", 8, 1, |ctx: &mut Ctx| {
        let seed = ctx.rng.next_u64();
        let k = 0.2 + 0.5 * ctx.rng.f64();
        let graph = Arc::new(Graph::chain(2));
        let manifest = sm_manifest((4, 4, 1), 8); // d = 136
        let d = manifest.d_pad;
        let mut nodes: Vec<CEclNode> = (0..2)
            .map(|i| {
                CEclNode::new(
                    &sm_ctx(i, &graph, seed, manifest.clone()),
                    CodecSpec::RandK { k_frac: k, mode: WireMode::Explicit },
                    1.0,
                    0,
                    DualRule::CompressDiff,
                )
                .unwrap()
            })
            .collect();
        let mut ws: Vec<Vec<f32>> = (0..2u64)
            .map(|i| {
                let mut rng = Pcg::derive(seed, &[9999, i]);
                (0..d).map(|_| rng.normal_f32()).collect()
            })
            .collect();
        let rounds = 40usize;
        let mut kept = 0.0f64;
        let mut total = 0.0f64;
        for round in 0..rounds {
            // Inspect what each node is about to ship.
            for i in 0..2usize {
                let to = 1 - i;
                let sign = graph.edge_sign(i, to);
                let taa = 2.0 * nodes[i].alpha() * sign;
                let y_dense: Vec<f32> = nodes[i].dual_state()
                    .row(0)
                    .iter()
                    .zip(&ws[i])
                    .map(|(&zv, &wv)| zv - taa * wv)
                    .collect();
                total += y_dense
                    .iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum::<f64>();
                let mut rng = Pcg::derive(
                    seed,
                    &[streams::EDGE_MASK, 0, round as u64, to as u64],
                );
                let mask = RandK::new(k).sample_mask(d, &mut rng);
                kept += CooVec::gather(&y_dense, &mask).norm2_sq();
            }
            drive_round(&mut nodes, &mut ws, round);
        }
        let measured = kept / total;
        prop_assert!(
            (measured - k).abs() < 0.12,
            "kept energy fraction {measured} vs tau=k={k}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Round policies: bounded staleness
// ---------------------------------------------------------------------

#[test]
fn prop_async_staleness_never_exceeds_bound() {
    // Across random staleness budgets, straggler factors, link models,
    // and seeds, an `async:<s>` run must (a) complete every round
    // without deadlock and (b) never consume a dual older than `s`
    // rounds — `SimOutcome::max_staleness` is the largest lag any
    // machine ever folded in, and the machines additionally hard-error
    // inside `round_end` if the bound is broken.
    use cecl::sim::{simulate, NodeSetup, NullLocal, Schedule, SimConfig};

    check("async-staleness-bound", 12, 4, |ctx: &mut Ctx| {
        let s = 1 + ctx.rng.below(3); // staleness budget 1..=3
        let n = 4 + (ctx.size % 3); // ring of 4..=6 nodes
        let rounds = 6 + ctx.rng.below(5);
        let seed = ctx.rng.next_u64();
        let policy = RoundPolicy::Async { max_staleness: s };
        let graph = Arc::new(Graph::ring(n));
        let alg = if ctx.rng.bernoulli(0.5) {
            AlgorithmSpec::CEcl {
                k_frac: 0.3,
                theta: 1.0,
                dense_first_epoch: false,
            }
        } else {
            AlgorithmSpec::DPsgd
        };
        let manifest = sm_manifest((2, 2, 1), 3);
        let ws: Vec<Vec<f32>> =
            (0..n).map(|_| ctx.vec_f32(manifest.d_pad)).collect();
        let setups: Vec<NodeSetup> = ws
            .into_iter()
            .enumerate()
            .map(|(i, w)| {
                let mut bctx = sm_ctx(i, &graph, seed, manifest.clone());
                bctx.round_policy = policy;
                NodeSetup {
                    machine: build_machine(&alg, &bctx).unwrap(),
                    local: Box::new(NullLocal),
                    w,
                }
            })
            .collect();
        let cfg = SimConfig {
            link: if ctx.rng.bernoulli(0.5) {
                cecl::sim::LinkSpec::Lossy {
                    latency_us: 200 + ctx.rng.below(2_000) as u64,
                    mbit_per_sec: 20.0,
                    drop_p: 0.2 * ctx.rng.f64(),
                }
            } else {
                cecl::sim::LinkSpec::Constant {
                    latency_us: 200 + ctx.rng.below(4_000) as u64,
                }
            },
            compute_ns_per_step: 500_000,
            stragglers: vec![(ctx.rng.below(n), 1.0 + 7.0 * ctx.rng.f64())],
            ..SimConfig::default()
        };
        let sched = Schedule::new(rounds, 1, 2, rounds);
        let out = simulate(&graph, &cfg, seed, &sched, setups, policy, false)
            .map_err(|e| format!("async sim failed: {e}"))?;
        prop_assert!(
            out.max_staleness <= s,
            "lag {} exceeds budget {s} (n={n}, rounds={rounds}, alg={})",
            out.max_staleness,
            alg.name()
        );
        prop_assert!(
            out.meter.total_msgs() as usize == rounds * 2 * n,
            "every node must still send every round: {} msgs",
            out.meter.total_msgs()
        );
        Ok(())
    });
}

#[test]
fn prop_powergossip_async_staleness_never_exceeds_bound() {
    // PowerGossip's conversation counters under async rounds: across
    // random staleness budgets, iteration counts, stragglers, and link
    // latencies, the run must complete every round without deadlock
    // (multi-phase conversations straddling rounds and all) and the
    // per-edge conversation clock must never lag past the budget.
    // Message counts are NOT one-per-edge-per-round here — PowerGossip
    // is multi-phase and trailing conversations may be abandoned at
    // shutdown — so only the bound and liveness are asserted.
    use cecl::sim::{simulate, NodeSetup, NullLocal, Schedule, SimConfig};

    check("pg-async-staleness-bound", 10, 4, |ctx: &mut Ctx| {
        let s = 1 + ctx.rng.below(3); // staleness budget 1..=3
        let n = 4 + (ctx.size % 3); // ring of 4..=6 nodes
        let rounds = 5 + ctx.rng.below(4);
        let seed = ctx.rng.next_u64();
        let policy = RoundPolicy::Async { max_staleness: s };
        let graph = Arc::new(Graph::ring(n));
        let alg = AlgorithmSpec::PowerGossip {
            iters: 1 + ctx.rng.below(2),
        };
        let manifest = sm_manifest((2, 2, 1), 3);
        let ws: Vec<Vec<f32>> =
            (0..n).map(|_| ctx.vec_f32(manifest.d_pad)).collect();
        let setups: Vec<NodeSetup> = ws
            .into_iter()
            .enumerate()
            .map(|(i, w)| {
                let mut bctx = sm_ctx(i, &graph, seed, manifest.clone());
                bctx.round_policy = policy;
                NodeSetup {
                    machine: build_machine(&alg, &bctx).unwrap(),
                    local: Box::new(NullLocal),
                    w,
                }
            })
            .collect();
        let cfg = SimConfig {
            link: cecl::sim::LinkSpec::Constant {
                latency_us: 200 + ctx.rng.below(4_000) as u64,
            },
            compute_ns_per_step: 500_000,
            stragglers: vec![(ctx.rng.below(n), 1.0 + 7.0 * ctx.rng.f64())],
            ..SimConfig::default()
        };
        let sched = Schedule::new(rounds, 1, 2, rounds);
        let out = simulate(&graph, &cfg, seed, &sched, setups, policy, false)
            .map_err(|e| format!("async PowerGossip sim failed: {e}"))?;
        prop_assert!(
            out.max_staleness <= s,
            "conversation lag {} exceeds budget {s} (n={n}, \
             rounds={rounds}, alg={})",
            out.max_staleness,
            alg.name()
        );
        prop_assert!(
            out.meter.total_bytes() > 0,
            "PowerGossip sent no traffic"
        );
        Ok(())
    });
}

#[test]
fn prop_edge_rebirth_never_reuses_stale_codec_state() {
    // The per-edge lifecycle satellite: remove→re-add of an edge under
    // the STATEFUL codecs (`ef+top_k` error-feedback residuals,
    // `low_rank:2` q̂ warm starts) must never resurrect the old
    // incarnation's state — the reborn machine's first frame must be
    // byte-identical to a brand-new codec instance encoding the
    // warm-started dual's y (z = α·a·w ⇒ y = −α·a·w) under the fresh
    // edge epoch.  A negative control pins that the property has teeth:
    // a codec that kept its state encodes a DIFFERENT frame than a
    // fresh one.
    use cecl::compress::EdgeCodec as _;

    check("edge-rebirth-fresh-codec", 8, 1, |ctx: &mut Ctx| {
        let seed = ctx.rng.next_u64();
        let specs = [
            CodecSpec::parse("ef+top_k:0.3").unwrap(),
            CodecSpec::parse("low_rank:2").unwrap(),
        ];
        for spec in specs {
            let graph = Arc::new(Graph::chain(2));
            let manifest = sm_manifest((3, 3, 1), 4);
            let d = manifest.d_pad;
            let mut nodes: Vec<CEclNode> = (0..2)
                .map(|i| {
                    CEclNode::new(
                        &sm_ctx(i, &graph, seed, manifest.clone()),
                        spec.clone(),
                        0.9,
                        0,
                        DualRule::CompressY,
                    )
                    .unwrap()
                })
                .collect();
            let mut ws: Vec<Vec<f32>> = (0..2u64)
                .map(|i| {
                    let mut rng = Pcg::derive(seed, &[4242, i]);
                    (0..d).map(|_| rng.normal_f32()).collect()
                })
                .collect();
            // Rounds 0..2 accumulate per-edge codec state (EF
            // residuals / q̂ warm starts) and nonzero duals.
            let mut view = TopologyView::full(graph.edges().len());
            for round in 0..3 {
                drive_round_view(&mut nodes, &mut ws, round, &view);
            }
            // Churn: the edge dies and is reborn activating at round 3.
            view.kill_edge(0);
            view.revive_edge(0, 3);
            let mut out = Outbox::new();
            for (i, node) in nodes.iter_mut().enumerate() {
                NodeStateMachine::on_topology(node, &view, &mut ws[i],
                                              &mut out)
                    .unwrap();
            }
            prop_assert!(out.is_empty(), "{}: topology sync sent", spec.name());
            // The reborn machine's first frame...
            NodeStateMachine::round_begin(&mut nodes[0], 3, &view,
                                          &mut ws[0], &mut out)
                .unwrap();
            let msgs: Vec<(usize, Msg)> = out.drain().collect();
            prop_assert!(msgs.len() == 1, "{}: one neighbor", spec.name());
            let frame = msgs
                .into_iter()
                .next()
                .unwrap()
                .1
                .into_frame()
                .map_err(|e| e.to_string())?;
            // ...must equal a brand-new codec encoding the warm-started
            // y = z − 2αa·w = αa·w − 2αa·w = −αa·w under epoch 1.
            let alpha = nodes[0].alpha();
            let a = graph.edge_sign(0, 1);
            let y: Vec<f32> =
                ws[0].iter().map(|&wv| -alpha * a * wv).collect();
            let mut fresh = spec.build();
            let mats: Vec<(usize, usize, usize)> = manifest
                .matrix_views()
                .into_iter()
                .map(|(_, off, r, c)| (off, r, c))
                .collect();
            let vecs: Vec<(usize, usize)> = manifest
                .vector_views()
                .into_iter()
                .map(|(_, off, len)| (off, len))
                .collect();
            fresh.bind_layout(&mats, &vecs);
            let ec = EdgeCtx {
                seed,
                edge: 0,
                round: 3,
                receiver: 1,
                dim: d,
                epoch: 1,
            };
            let expect = fresh.encode(&y, &ec);
            prop_assert!(
                frame.bytes() == expect.bytes(),
                "{}: reborn frame != fresh-codec frame (stale state \
                 resurrected?)",
                spec.name()
            );
            // Negative control: a codec that kept its state across the
            // same rounds encodes something ELSE than a fresh one.
            let mut used = spec.build();
            used.bind_layout(&mats, &vecs);
            for round in 0..3 {
                let x: Vec<f32> =
                    (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
                let ec_r = EdgeCtx {
                    seed,
                    edge: 0,
                    round,
                    receiver: 1,
                    dim: d,
                    epoch: 0,
                };
                let _ = used.encode(&x, &ec_r);
            }
            let mut fresh2 = spec.build();
            fresh2.bind_layout(&mats, &vecs);
            let ec4 = EdgeCtx {
                seed,
                edge: 0,
                round: 4,
                receiver: 1,
                dim: d,
                epoch: 0,
            };
            let stale_frame = used.encode(&y, &ec4);
            let fresh_frame = fresh2.encode(&y, &ec4);
            prop_assert!(
                stale_frame.bytes() != fresh_frame.bytes(),
                "{}: statefulness control failed — stale == fresh",
                spec.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_rival_machines_async_staleness_never_exceeds_bound() {
    // CHOCO-SGD and LEAD under `async:<s>` obey the same contract as
    // C-ECL: every round completes without deadlock, no replica or
    // dual older than `s` rounds is ever folded, and both stay
    // one-frame-per-neighbor-per-round on the wire (they are
    // single-phase gossip protocols, so message counts are exact).
    use cecl::sim::{simulate, NodeSetup, NullLocal, Schedule, SimConfig};

    check("rival-async-staleness-bound", 10, 4, |ctx: &mut Ctx| {
        let s = 1 + ctx.rng.below(3); // staleness budget 1..=3
        let n = 4 + (ctx.size % 3); // ring of 4..=6 nodes
        let rounds = 6 + ctx.rng.below(4);
        let seed = ctx.rng.next_u64();
        let policy = RoundPolicy::Async { max_staleness: s };
        let graph = Arc::new(Graph::ring(n));
        let alg = if ctx.rng.bernoulli(0.5) {
            AlgorithmSpec::Choco {
                codec: CodecSpec::RandK {
                    k_frac: 0.3,
                    mode: WireMode::Explicit,
                },
            }
        } else {
            AlgorithmSpec::Lead { codec: CodecSpec::Qsgd { bits: 4 } }
        };
        let manifest = sm_manifest((2, 2, 1), 3);
        let ws: Vec<Vec<f32>> =
            (0..n).map(|_| ctx.vec_f32(manifest.d_pad)).collect();
        let setups: Vec<NodeSetup> = ws
            .into_iter()
            .enumerate()
            .map(|(i, w)| {
                let mut bctx = sm_ctx(i, &graph, seed, manifest.clone());
                bctx.round_policy = policy;
                NodeSetup {
                    machine: build_machine(&alg, &bctx).unwrap(),
                    local: Box::new(NullLocal),
                    w,
                }
            })
            .collect();
        let cfg = SimConfig {
            link: if ctx.rng.bernoulli(0.5) {
                cecl::sim::LinkSpec::Lossy {
                    latency_us: 200 + ctx.rng.below(2_000) as u64,
                    mbit_per_sec: 20.0,
                    drop_p: 0.2 * ctx.rng.f64(),
                }
            } else {
                cecl::sim::LinkSpec::Constant {
                    latency_us: 200 + ctx.rng.below(4_000) as u64,
                }
            },
            compute_ns_per_step: 500_000,
            stragglers: vec![(ctx.rng.below(n), 1.0 + 7.0 * ctx.rng.f64())],
            ..SimConfig::default()
        };
        let sched = Schedule::new(rounds, 1, 2, rounds);
        let out = simulate(&graph, &cfg, seed, &sched, setups, policy, false)
            .map_err(|e| format!("async {} sim failed: {e}", alg.name()))?;
        prop_assert!(
            out.max_staleness <= s,
            "lag {} exceeds budget {s} (n={n}, rounds={rounds}, alg={})",
            out.max_staleness,
            alg.name()
        );
        prop_assert!(
            out.meter.total_msgs() as usize == rounds * 2 * n,
            "{}: every node must still send every round: {} msgs",
            alg.name(),
            out.meter.total_msgs()
        );
        Ok(())
    });
}

#[test]
fn prop_rival_edge_rebirth_never_reuses_stale_codec_state() {
    // The PR-5 lifecycle contract extended over the rival machines:
    // remove→re-add of an edge under the stateful `ef+top_k` codec must
    // give the reborn incarnation a zeroed replica AND a fresh codec.
    // Both CHOCO-SGD and LEAD encode `q = (buffer) − replica` in
    // round_begin, so the reborn machine's first frame must be
    // byte-identical to a brand-new codec encoding the raw buffer
    // under the fresh edge epoch.  A no-churn control pins that the
    // property has teeth: without the rebirth, the accumulated replica
    // and EF residual produce a DIFFERENT frame.
    use cecl::compress::EdgeCodec as _;

    check("rival-rebirth-fresh-codec", 6, 1, |ctx: &mut Ctx| {
        let seed = ctx.rng.next_u64();
        let spec = CodecSpec::parse("ef+top_k:0.3").unwrap();
        let graph = Arc::new(Graph::chain(2));
        let manifest = sm_manifest((3, 3, 1), 4);
        let d = manifest.d_pad;
        let mats: Vec<(usize, usize, usize)> = manifest
            .matrix_views()
            .into_iter()
            .map(|(_, off, r, c)| (off, r, c))
            .collect();
        let vecs: Vec<(usize, usize)> = manifest
            .vector_views()
            .into_iter()
            .map(|(_, off, len)| (off, len))
            .collect();
        for kind in ["choco", "lead"] {
            let build = |i: usize| -> Box<dyn NodeStateMachine> {
                let bctx = sm_ctx(i, &graph, seed, manifest.clone());
                match kind {
                    "choco" => {
                        Box::new(ChocoNode::new(&bctx, spec.clone()).unwrap())
                    }
                    _ => Box::new(LeadNode::new(&bctx, spec.clone()).unwrap()),
                }
            };
            let make_ws = || -> Vec<Vec<f32>> {
                (0..2u64)
                    .map(|i| {
                        let mut rng = Pcg::derive(seed, &[5151, i]);
                        (0..d).map(|_| rng.normal_f32()).collect()
                    })
                    .collect()
            };
            let mut nodes: Vec<Box<dyn NodeStateMachine>> =
                (0..2).map(build).collect();
            let mut ws = make_ws();
            // Rounds 0..2 accumulate replicas and EF residuals.
            let mut view = TopologyView::full(graph.edges().len());
            for round in 0..3 {
                drive_round_dyn(&mut nodes, &mut ws, round, &view);
            }
            // Churn: the edge dies and is reborn activating at round 3.
            view.kill_edge(0);
            view.revive_edge(0, 3);
            let mut out = Outbox::new();
            for (i, node) in nodes.iter_mut().enumerate() {
                node.on_topology(&view, &mut ws[i], &mut out).unwrap();
            }
            prop_assert!(out.is_empty(), "{kind}: topology sync sent");
            // The reborn machine's first frame...
            nodes[0].round_begin(3, &view, &mut ws[0], &mut out).unwrap();
            let msgs: Vec<(usize, Msg)> = out.drain().collect();
            prop_assert!(msgs.len() == 1, "{kind}: one neighbor");
            let frame = msgs
                .into_iter()
                .next()
                .unwrap()
                .1
                .into_frame()
                .map_err(|e| e.to_string())?;
            // ...must equal a brand-new codec encoding the raw buffer
            // (replica = 0 ⇒ q = w) under epoch 1.
            let mut fresh = spec.build();
            fresh.bind_layout(&mats, &vecs);
            let ec = EdgeCtx {
                seed,
                edge: 0,
                round: 3,
                receiver: 1,
                dim: d,
                epoch: 1,
            };
            let expect = fresh.encode(&ws[0], &ec);
            prop_assert!(
                frame.bytes() == expect.bytes(),
                "{kind}: reborn frame != fresh-codec frame (stale replica \
                 or EF state resurrected?)"
            );
            // No-churn control: the same machine driven without the
            // rebirth carries replica + EF state into round 3 and
            // encodes something ELSE.
            let mut ctrl: Vec<Box<dyn NodeStateMachine>> =
                (0..2).map(build).collect();
            let mut cws = make_ws();
            let static_view = TopologyView::full(graph.edges().len());
            for round in 0..3 {
                drive_round_dyn(&mut ctrl, &mut cws, round, &static_view);
            }
            let mut cout = Outbox::new();
            ctrl[0]
                .round_begin(3, &static_view, &mut cws[0], &mut cout)
                .unwrap();
            let cframe = cout
                .drain()
                .next()
                .unwrap()
                .1
                .into_frame()
                .map_err(|e| e.to_string())?;
            let mut fresh2 = spec.build();
            fresh2.bind_layout(&mats, &vecs);
            let ec0 = EdgeCtx { epoch: 0, ..ec };
            let fresh_frame = fresh2.encode(&cws[0], &ec0);
            prop_assert!(
                cframe.bytes() != fresh_frame.bytes(),
                "{kind}: statefulness control failed — a live edge's \
                 round-3 frame matched a fresh codec on the raw buffer"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_low_rank_codec_roundtrips_within_rank_error() {
    // `low_rank:R` on an exactly rank-R matrix: with at least one
    // power-iteration refinement per rank, every shipped q factor lies
    // in the residual's row space, so R greedy deflation steps project
    // the whole row space away — encode→decode reconstructs the input
    // to f32 rounding, for any rank/shape/seed.  The wire size is the
    // PowerGossip formula `R·(rows+cols)·4` exactly.
    use cecl::compress::{EdgeCodec, EdgeCtx, LowRankCodec};

    check("low-rank-roundtrip", 14, 8, |ctx: &mut Ctx| {
        let rank = 1 + ctx.rng.below(3);
        let rows = 4 + ctx.rng.below(12);
        let cols = 3 + ctx.rng.below(9);
        let dim = rows * cols;
        // Exactly rank-R input: sum of R random outer products.
        let mut m = vec![0.0f32; dim];
        for _ in 0..rank {
            let sigma = (0.5 + 4.0 * ctx.rng.f64()) as f32;
            let u: Vec<f32> =
                (0..rows).map(|_| ctx.rng.normal_f32()).collect();
            let v: Vec<f32> =
                (0..cols).map(|_| ctx.rng.normal_f32()).collect();
            for r in 0..rows {
                for c in 0..cols {
                    m[r * cols + c] += sigma * u[r] * v[c];
                }
            }
        }
        let norm: f32 = m.iter().map(|x| x * x).sum();
        if norm < 1e-6 {
            return Ok(()); // degenerate draw, nothing to measure
        }
        let seed = ctx.rng.next_u64();
        let mut codec = LowRankCodec::new(rank, 2);
        codec.bind_layout(&[(0, rows, cols)], &[]);
        let mut rel = f32::MAX;
        for round in 0..3 {
            let ectx = EdgeCtx {
                seed,
                edge: 1,
                round,
                receiver: 0,
                dim,
                epoch: 0,
            };
            let frame = codec.encode(&m, &ectx);
            prop_assert!(
                frame.wire_bytes() == rank * (rows + cols) * 4,
                "rank {rank} ({rows}x{cols}): {} wire bytes",
                frame.wire_bytes()
            );
            let y = codec
                .decode(&frame, &ectx)
                .map_err(|e| format!("decode failed: {e}"))?;
            let err: f32 = y
                .iter()
                .zip(&m)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            rel = err / norm;
        }
        prop_assert!(
            rel < 1e-2,
            "rank-{rank} ({rows}x{cols}): rel err {rel} after warm start"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Graph invariants
// ---------------------------------------------------------------------

#[test]
fn prop_random_graphs_connected_mh_stochastic() {
    check("graph-mh", 20, 24, |ctx: &mut Ctx| {
        let n = (ctx.size + 3).min(24);
        // `random_connected` is the explicit-connectivity sampler; the
        // plain `random` is honest G(n, p) and may disconnect.
        let g = Graph::random_connected(n, 0.3 + ctx.rng.f64() * 0.5,
                                        ctx.rng.next_u64());
        prop_assert!(g.is_connected(), "disconnected");
        let w = g.mh_weights();
        for i in 0..n {
            let row: f64 = w[i].iter().sum();
            prop_assert!((row - 1.0).abs() < 1e-9, "row {i} sums to {row}");
            for j in 0..n {
                prop_assert!(w[i][j] >= -1e-12, "negative weight");
                prop_assert!(
                    (w[i][j] - w[j][i]).abs() < 1e-12,
                    "asymmetric at ({i},{j})"
                );
            }
        }
        // Edge-sign pairing (Eq. 2): A_{i|j} + A_{j|i} = 0.
        for &(i, j) in g.edges() {
            prop_assert!(
                g.edge_sign(i, j) + g.edge_sign(j, i) == 0.0,
                "sign pairing"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Linear algebra
// ---------------------------------------------------------------------

#[test]
fn prop_cholesky_solves_random_spd() {
    check("cholesky", 20, 24, |ctx: &mut Ctx| {
        let n = (ctx.size % 24).max(2);
        let b = Mat::randn(n + 3, n, &mut ctx.rng);
        let mut a = b.gram();
        a.add_diag(0.3);
        let x_true = ctx.vec_f64(n);
        let rhs = a.matvec(&x_true);
        let x = Cholesky::new(&a)
            .ok_or_else(|| "not SPD".to_string())?
            .solve(&rhs);
        for i in 0..n {
            prop_assert!(
                (x[i] - x_true[i]).abs() < 1e-6,
                "solve mismatch at {i}: {} vs {}",
                x[i],
                x_true[i]
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Theory formulas (Theorem 1 arithmetic)
// ---------------------------------------------------------------------

#[test]
fn prop_theta_domain_contains_one_and_bound_below_one() {
    // Whenever τ is above the threshold, Eq. (15) contains θ = 1 and the
    // bound at θ = 1 contracts (< 1) — the paper's Lemma 6.
    check("theta-domain", 50, 1, |ctx: &mut Ctx| {
        let delta = ctx.rng.f64() * 0.95;
        let threshold = tau_threshold(delta);
        let tau = threshold + (1.0 - threshold) * (0.05 + 0.9 * ctx.rng.f64());
        match theta_domain(tau, delta) {
            Some((lo, hi)) => {
                prop_assert!(
                    lo < 1.0 && 1.0 <= hi + 1e-12,
                    "domain ({lo},{hi}) misses 1 (tau={tau}, delta={delta})"
                );
                let rho = rate_bound(1.0, tau, delta);
                prop_assert!(rho < 1.0, "bound {rho} >= 1");
                Ok(())
            }
            None => Err(format!(
                "domain empty above threshold: tau={tau} delta={delta}"
            )),
        }
    });
}

#[test]
fn prop_rate_bound_monotone_in_tau() {
    // Less compression (larger τ) never worsens the bound.
    check("bound-monotone", 50, 1, |ctx: &mut Ctx| {
        let delta = ctx.rng.f64() * 0.9;
        let theta = 0.2 + ctx.rng.f64();
        let t1 = ctx.rng.f64();
        let t2 = t1 + (1.0 - t1) * ctx.rng.f64();
        prop_assert!(
            rate_bound(theta, t2, delta) <= rate_bound(theta, t1, delta) + 1e-12,
            "bound not monotone: tau {t1}->{t2}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Data partitioner
// ---------------------------------------------------------------------

#[test]
fn prop_heterogeneous_partition_shapes() {
    check("partition", 20, 16, |ctx: &mut Ctx| {
        let nodes = (ctx.size % 16).max(2);
        let per = 1 + ctx.rng.below(9);
        let sets = node_classes(
            Partition::Heterogeneous { classes_per_node: per },
            nodes,
            10,
            ctx.rng.next_u64(),
        );
        prop_assert!(sets.len() == nodes, "wrong node count");
        for s in &sets {
            prop_assert!(s.len() == per, "wrong class count");
            let mut d = s.clone();
            d.dedup();
            prop_assert!(d.len() == per, "duplicate classes");
            prop_assert!(s.iter().all(|&c| c < 10), "class out of range");
        }
        Ok(())
    });
}

#[test]
fn prop_dirichlet_counts_partition_every_sample_exactly_once() {
    // The Dirichlet(α) split apportions exactly `train_per_node`
    // samples per node (largest remainder never drops or duplicates a
    // sample) for every α, node count, and class count — and the whole
    // split is a pure function of the seed.
    check("dirichlet-partition", 16, 12, |ctx: &mut Ctx| {
        let nodes = (ctx.size % 12).max(2);
        let classes = 4 + ctx.rng.below(7); // 4..=10
        let train = 40 + ctx.rng.below(200);
        let alpha = 0.05 + 2.0 * ctx.rng.f64();
        let seed = ctx.rng.next_u64();
        let counts = dirichlet_class_counts(nodes, classes, train, alpha, seed);
        prop_assert!(counts.len() == nodes, "node count");
        for (i, c) in counts.iter().enumerate() {
            prop_assert!(c.len() == classes, "node {i}: class count");
            let total: usize = c.iter().sum();
            prop_assert!(
                total == train,
                "node {i} holds {total} samples, not {train}"
            );
        }
        let again = dirichlet_class_counts(nodes, classes, train, alpha, seed);
        prop_assert!(counts == again, "dirichlet split not deterministic");
        Ok(())
    });
}

#[test]
fn prop_dirichlet_datasets_realize_the_drawn_counts() {
    // End to end through the generator: the per-node datasets built for
    // a Dirichlet partition hold exactly the drawn per-class counts —
    // every sample the apportionment assigned shows up exactly once in
    // the node's label histogram.
    check("dirichlet-datasets", 6, 6, |ctx: &mut Ctx| {
        let nodes = (ctx.size % 6).max(2);
        let alpha = 0.1 + ctx.rng.f64();
        let seed = ctx.rng.next_u64();
        let spec = SyntheticSpec::for_dataset("p", 4, 4, 1, 10, seed);
        let train = 50;
        let (trains, test) = build_node_datasets(
            &spec,
            Partition::Dirichlet { alpha },
            nodes,
            train,
            80,
        );
        let counts = dirichlet_class_counts(nodes, 10, train, alpha, seed);
        prop_assert!(trains.len() == nodes, "node count");
        for (i, ds) in trains.iter().enumerate() {
            prop_assert!(ds.n == train, "node {i}: {} samples", ds.n);
            let mut hist = vec![0usize; 10];
            for &y in &ds.y {
                hist[y as usize] += 1;
            }
            prop_assert!(
                hist == counts[i],
                "node {i}: labels don't realize the Dirichlet draw"
            );
        }
        prop_assert!(test.n == 80, "test size");
        Ok(())
    });
}

#[test]
fn prop_dirichlet_alpha_to_infinity_recovers_homogeneous_split() {
    // α → ∞ pins the proportions at 1/classes, so the apportioned
    // counts converge to the homogeneous split (±1 from rounding) and
    // the skew statistic sits on the balanced floor.
    check("dirichlet-large-alpha", 12, 10, |ctx: &mut Ctx| {
        let nodes = (ctx.size % 10).max(2);
        let classes = 10usize;
        let train = 100 * (1 + ctx.rng.below(4));
        let seed = ctx.rng.next_u64();
        let counts =
            dirichlet_class_counts(nodes, classes, train, 1e9, seed);
        let per = train / classes;
        for (i, c) in counts.iter().enumerate() {
            for (cls, &cnt) in c.iter().enumerate() {
                prop_assert!(
                    cnt.abs_diff(per) <= 1,
                    "node {i} class {cls}: {cnt} vs homogeneous {per}"
                );
            }
        }
        let skew = label_skew(&counts);
        prop_assert!(
            skew < 0.1 + 2.0 / train as f64,
            "skew {skew} at alpha=1e9"
        );
        Ok(())
    });
}

#[test]
fn dirichlet_alpha_point_one_pins_heavy_label_skew() {
    // The head-to-head operating point (α = 0.1, 8 nodes, 10 classes,
    // 500 samples/node — the acceptance scenario's split): the mean
    // max-class share must sit well above both the balanced 0.1 floor
    // and the near-homogeneous α = 100 draw, and reproduce exactly
    // from the seed.
    let counts = dirichlet_class_counts(8, 10, 500, 0.1, 42);
    let skew = label_skew(&counts);
    assert_eq!(
        skew,
        label_skew(&dirichlet_class_counts(8, 10, 500, 0.1, 42)),
        "skew statistic not reproducible from the seed"
    );
    assert!(skew > 0.35, "alpha=0.1 skew {skew} below the pinned floor");
    let tame = label_skew(&dirichlet_class_counts(8, 10, 500, 100.0, 42));
    assert!(tame < 0.18, "alpha=100 skew {tame} above the homogeneous band");
    assert!(
        skew > 2.0 * tame,
        "skew ladder not monotone in alpha: {skew} !> 2 x {tame}"
    );
}

// ---------------------------------------------------------------------
// RNG stream separation
// ---------------------------------------------------------------------

#[test]
fn prop_derive_streams_uncorrelated() {
    check("rng-streams", 20, 1, |ctx: &mut Ctx| {
        let seed = ctx.rng.next_u64();
        let a = ctx.rng.next_u64();
        let b = ctx.rng.next_u64();
        if a == b {
            return Ok(());
        }
        let mut ra = Pcg::derive(seed, &[a]);
        let mut rb = Pcg::derive(seed, &[b]);
        let matches =
            (0..256).filter(|_| ra.next_u32() == rb.next_u32()).count();
        prop_assert!(matches < 4, "streams correlated: {matches}/256");
        Ok(())
    });
}
