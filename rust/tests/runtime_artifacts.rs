//! Runtime <-> artifact integration: load the real AOT-compiled HLO
//! modules through PJRT and cross-check them against independent rust
//! implementations.  Requires `make artifacts` (tests self-skip when the
//! artifacts directory is absent).

use std::sync::Arc;

use cecl::compress::RandK;
use cecl::model::Manifest;
use cecl::runtime::{native, Engine, In, ModelRuntime};
use cecl::util::rng::Pcg;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(Manifest::load(dir).expect("manifest parses"))
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

#[test]
fn smoke_artifact_executes() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_hlo(&m.smoke).unwrap();
    // smoke = (x * y + 1,)
    let out = exe
        .run(&[
            In::F32(&[1.0, 2.0, 3.0, 4.0], &[4]),
            In::F32(&[10.0, 10.0, 10.0, 10.0], &[4]),
        ])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0], vec![11.0, 21.0, 31.0, 41.0]);
}

#[test]
fn train_step_with_alpha_zero_is_sgd_direction() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let ds = m.dataset("fashion").unwrap();
    let rt = ModelRuntime::load(&engine, ds).unwrap();
    let w = ds.load_init_w().unwrap();
    let zeros = vec![0.0f32; ds.d_pad];
    let x = randn(ds.batch * ds.sample_len(), 1);
    let y: Vec<i32> = (0..ds.batch as i32).map(|i| i % 10).collect();
    let eta = 0.01f32;

    let (w1, loss1) = rt.train_step(&w, &zeros, &x, &y, eta, 0.0).unwrap();
    assert!(loss1.is_finite() && loss1 > 0.0);
    // Same inputs with half the learning rate: step size halves (pure
    // SGD linearity in eta for fixed gradient).
    let (w2, loss2) = rt.train_step(&w, &zeros, &x, &y, eta / 2.0, 0.0).unwrap();
    assert!((loss1 - loss2).abs() < 1e-5, "loss must not depend on eta");
    for i in (0..ds.d_pad).step_by(997) {
        let step1 = w1[i] - w[i];
        let step2 = w2[i] - w[i];
        assert!(
            (step1 - 2.0 * step2).abs() <= 1e-5 + 1e-2 * step1.abs(),
            "eta linearity at {i}: {step1} vs 2*{step2}"
        );
    }
}

#[test]
fn train_step_prox_shrinks_towards_zsum() {
    // With huge alpha_deg and zsum = alpha * deg * target, the Eq. (6)
    // closed form must land near target/deg... more precisely
    // w ≈ zsum / alpha_deg when alpha_deg >> 1/eta.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let ds = m.dataset("fashion").unwrap();
    let rt = ModelRuntime::load(&engine, ds).unwrap();
    let w = ds.load_init_w().unwrap();
    let target = randn(ds.d_pad, 3);
    let alpha_deg = 1e6f32;
    let zsum: Vec<f32> = target.iter().map(|t| t * alpha_deg).collect();
    let x = randn(ds.batch * ds.sample_len(), 2);
    let y: Vec<i32> = vec![0; ds.batch];
    let (w_next, _) = rt.train_step(&w, &zsum, &x, &y, 0.05, alpha_deg).unwrap();
    for i in (0..ds.d_pad).step_by(631) {
        assert!(
            (w_next[i] - target[i]).abs() < 1e-3,
            "prox limit at {i}: {} vs {}",
            w_next[i],
            target[i]
        );
    }
}

#[test]
fn eval_batch_counts_are_sane() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let ds = m.dataset("fashion").unwrap();
    let rt = ModelRuntime::load(&engine, ds).unwrap();
    let w = ds.load_init_w().unwrap();
    let x = randn(ds.eval_batch * ds.sample_len(), 5);
    let y: Vec<i32> = (0..ds.eval_batch as i32).map(|i| i % 10).collect();
    let (correct, loss_sum) = rt.eval_batch(&w, &x, &y).unwrap();
    assert!(correct >= 0.0 && correct <= ds.eval_batch as f32);
    assert_eq!(correct, correct.round(), "correct must be integral");
    // Random init on random data: loss near ln(10) per sample.
    let per_sample = loss_sum / ds.eval_batch as f32;
    assert!(
        (per_sample - 10f32.ln()).abs() < 0.5,
        "per-sample loss {per_sample} far from ln(10)"
    );
}

#[test]
fn pjrt_dual_update_matches_native_twin() {
    // THE L1 cross-check: the Pallas dual_update artifact and the rust
    // native twin must agree elementwise on random inputs.
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let ds = m.dataset("fashion").unwrap();
    let rt = ModelRuntime::load(&engine, ds).unwrap();
    let d = ds.d_pad;
    let z = randn(d, 11);
    let w = randn(d, 12);
    let y = randn(d, 13);
    let op = RandK::new(0.2);
    let mut rng = Pcg::new(14);
    let mask_in = op.sample_mask(d, &mut rng);
    let mask_out = op.sample_mask(d, &mut rng);
    let mut mi = Vec::new();
    let mut mo = Vec::new();
    RandK::mask_to_dense(d, &mask_in, &mut mi);
    RandK::mask_to_dense(d, &mask_out, &mut mo);
    let ycomp: Vec<f32> = y.iter().zip(&mi).map(|(a, b)| a * b).collect();
    let theta = 0.85f32;
    let taa = -0.31f32;

    let (z_pjrt, y_pjrt) = rt
        .dual_update(&z, &w, &ycomp, &mi, &mo, theta, taa)
        .unwrap();
    let mut z_native = vec![0.0f32; d];
    let mut y_native = vec![0.0f32; d];
    native::dual_update_into(&z, &w, &ycomp, &mi, &mo, theta, taa,
                             &mut z_native, &mut y_native);
    for i in 0..d {
        assert!(
            (z_pjrt[i] - z_native[i]).abs() < 1e-5,
            "z mismatch at {i}: {} vs {}",
            z_pjrt[i],
            z_native[i]
        );
        assert!(
            (y_pjrt[i] - y_native[i]).abs() < 1e-5,
            "y mismatch at {i}: {} vs {}",
            y_pjrt[i],
            y_native[i]
        );
    }
}

#[test]
fn executables_are_thread_safe() {
    // 4 threads through the same Arc<ModelRuntime> (the coordinator's
    // sharing pattern).
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    let ds = m.dataset("fashion").unwrap();
    let rt = ModelRuntime::load(&engine, ds).unwrap();
    let w = Arc::new(ds.load_init_w().unwrap());
    let zeros = Arc::new(vec![0.0f32; ds.d_pad]);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let rt = Arc::clone(&rt);
                let w = Arc::clone(&w);
                let zeros = Arc::clone(&zeros);
                let ds = ds.clone();
                s.spawn(move || {
                    let x = randn(ds.batch * ds.sample_len(), 100 + t);
                    let y: Vec<i32> = vec![(t % 10) as i32; ds.batch];
                    for _ in 0..3 {
                        let (w2, loss) = rt
                            .train_step(&w, &zeros, &x, &y, 0.01, 0.0)
                            .unwrap();
                        assert!(loss.is_finite());
                        assert_eq!(w2.len(), ds.d_pad);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

#[test]
fn both_dataset_configs_load_and_run() {
    let Some(m) = manifest() else { return };
    let engine = Engine::cpu().unwrap();
    for name in ["fashion", "cifar"] {
        let ds = m.dataset(name).unwrap();
        let rt = ModelRuntime::load(&engine, ds).unwrap();
        let w = ds.load_init_w().unwrap();
        let x = randn(ds.batch * ds.sample_len(), 7);
        let y: Vec<i32> = vec![1; ds.batch];
        let (w2, loss) = rt.train_step(&w, &vec![0.0; ds.d_pad], &x, &y,
                                       0.01, 0.0).unwrap();
        assert!(loss.is_finite(), "{name} loss");
        assert!(w2.iter().all(|v| v.is_finite()), "{name} weights");
    }
}
