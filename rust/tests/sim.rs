//! Virtual-time engine test suite — all artifact-free:
//!
//! * deterministic replay: same seed ⇒ bit-identical `Report` (bytes,
//!   retransmits, virtual clock, every history record) for every
//!   algorithm on two different link models;
//! * zero-latency lossless link reproduces the threaded bus's byte
//!   accounting exactly for C-ECL / ECL / D-PSGD on ring and
//!   fully-connected graphs;
//! * drop-with-retransmit never under-counts meter bytes versus the
//!   lossless run;
//! * the acceptance run: a 512-node ring C-ECL experiment completes in
//!   one process and reports simulated time-to-accuracy.

use std::sync::Arc;

use cecl::algorithms::{build_machine, build_node, AlgorithmSpec, BuildCtx,
                       DualPath, NodeAlgorithm, RoundPolicy};
use cecl::comm::build_bus;
use cecl::compress::{hotpath_counters, reset_hotpath_counters, CodecSpec};
use cecl::coordinator::{run_simulated_native, ExecMode, ExperimentSpec};
use cecl::graph::Graph;
use cecl::model::DatasetManifest;
use cecl::sim::{simulate, LinkSpec, NodeSetup, NullLocal, Schedule, SimConfig};
use cecl::util::rng::Pcg;

fn exchange_manifest() -> DatasetManifest {
    // d = (2*2*1 + 1) * 3 = 15 parameters.
    DatasetManifest::synthetic_linear("x", (2, 2, 1), 3, 2, 2)
}

fn ctx(node: usize, graph: &Arc<Graph>, seed: u64, rounds: usize) -> BuildCtx {
    ctx_policy(node, graph, seed, rounds, RoundPolicy::Sync)
}

fn ctx_policy(node: usize, graph: &Arc<Graph>, seed: u64, rounds: usize,
              round_policy: RoundPolicy) -> BuildCtx {
    BuildCtx {
        node,
        graph: Arc::clone(graph),
        manifest: exchange_manifest(),
        seed,
        eta: 0.05,
        local_steps: 2,
        rounds_per_epoch: rounds,
        dual_path: DualPath::Native,
        runtime: None,
        round_policy,
    }
}

fn init_w(node: usize) -> Vec<f32> {
    let mut rng = Pcg::new(500 + node as u64);
    (0..exchange_manifest().d_pad)
        .map(|_| rng.normal_f32())
        .collect()
}

/// Per-node bytes + message count + final parameters after `rounds`
/// exchange-only rounds on the threaded bus.  The blocking
/// `NodeAlgorithm::exchange` loop IS the pre-refactor bulk-synchronous
/// schedule, so its trajectory doubles as the pre-async pin.
fn threaded_run(alg: &AlgorithmSpec, graph: &Arc<Graph>, seed: u64,
                rounds: usize) -> (Vec<u64>, u64, Vec<Vec<f32>>) {
    let (comms, meter) = build_bus(graph);
    let mut ws: Vec<Vec<f32>> = (0..graph.n()).map(init_w).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .into_iter()
            .zip(ws.iter_mut())
            .enumerate()
            .map(|(i, (comm, w))| {
                let graph = Arc::clone(graph);
                let alg = alg.clone();
                s.spawn(move || {
                    let mut node: Box<dyn NodeAlgorithm> =
                        build_node(&alg, &ctx(i, &graph, seed, rounds))
                            .unwrap();
                    for round in 0..rounds {
                        node.exchange(round, w, &comm).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    (
        (0..graph.n()).map(|i| meter.bytes_sent(i)).collect(),
        meter.total_msgs(),
        ws,
    )
}

fn threaded_bytes(alg: &AlgorithmSpec, graph: &Arc<Graph>, seed: u64,
                  rounds: usize) -> (Vec<u64>, u64) {
    let (bytes, msgs, _) = threaded_run(alg, graph, seed, rounds);
    (bytes, msgs)
}

/// Same protocol through the virtual-time engine on the given link.
fn simulated_run(alg: &AlgorithmSpec, graph: &Arc<Graph>, seed: u64,
                 rounds: usize, link: LinkSpec,
                 policy: RoundPolicy) -> (Vec<u64>, u64, u64, Vec<Vec<f32>>) {
    // One round per "epoch" with an eval only at the very end keeps the
    // schedule equivalent to the bare threaded loop above.
    let sched = Schedule::new(rounds, 1, 2, rounds);
    let setups: Vec<NodeSetup> = (0..graph.n())
        .map(|i| NodeSetup {
            machine: build_machine(
                alg,
                &ctx_policy(i, graph, seed, rounds, policy),
            )
            .unwrap(),
            local: Box::new(NullLocal),
            w: init_w(i),
        })
        .collect();
    let cfg = SimConfig { link, ..SimConfig::default() };
    let out = simulate(graph, &cfg, seed, &sched, setups, policy, false)
        .unwrap();
    (
        (0..graph.n()).map(|i| out.meter.bytes_sent(i)).collect(),
        out.meter.total_msgs(),
        out.meter.total_retransmit_bytes(),
        out.w,
    )
}

fn simulated_bytes(alg: &AlgorithmSpec, graph: &Arc<Graph>, seed: u64,
                   rounds: usize, link: LinkSpec) -> (Vec<u64>, u64, u64) {
    let (bytes, msgs, retrans, _) =
        simulated_run(alg, graph, seed, rounds, link, RoundPolicy::Sync);
    (bytes, msgs, retrans)
}

#[test]
fn ideal_link_matches_threaded_bus_byte_for_byte() {
    let algs = [
        AlgorithmSpec::CEcl {
            k_frac: 0.3,
            theta: 1.0,
            dense_first_epoch: false,
        },
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::DPsgd,
    ];
    for graph in [Arc::new(Graph::ring(5)), Arc::new(Graph::complete(4))] {
        for alg in &algs {
            let (bytes_t, msgs_t) = threaded_bytes(alg, &graph, 77, 3);
            let (bytes_s, msgs_s, retrans) =
                simulated_bytes(alg, &graph, 77, 3, LinkSpec::Ideal);
            assert_eq!(
                bytes_t, bytes_s,
                "{} on {}-node graph: per-node bytes diverged",
                alg.name(),
                graph.n()
            );
            assert_eq!(msgs_t, msgs_s, "{}: message counts", alg.name());
            assert_eq!(retrans, 0, "ideal link must not retransmit");
        }
    }
}

/// C-ECL over a codec spec (no warmup), for the codec-matrix tests.
fn cecl_codec(spec: &str) -> AlgorithmSpec {
    AlgorithmSpec::CEclCodec {
        codec: CodecSpec::parse(spec).unwrap(),
        theta: 1.0,
        dense_first_epoch: false,
    }
}

#[test]
fn every_codec_meters_identical_first_copy_bytes_on_both_engines() {
    // Acceptance pin: for EVERY codec, the threaded bus and the
    // virtual-time engine account identical first-copy bytes per node —
    // frames are serialized once and measured, never inferred.
    let graph = Arc::new(Graph::ring(5));
    for spec in ["identity", "rand_k:0.1", "rand_k:0.1:values", "top_k:0.1",
                 "qsgd:4", "sign", "low_rank:2", "ef+top_k:0.1",
                 "ef+low_rank:2"] {
        let alg = cecl_codec(spec);
        let (bytes_t, msgs_t) = threaded_bytes(&alg, &graph, 31, 3);
        let (bytes_s, msgs_s, retrans) =
            simulated_bytes(&alg, &graph, 31, 3, LinkSpec::Ideal);
        assert_eq!(bytes_t, bytes_s, "{spec}: per-node bytes diverged");
        assert_eq!(msgs_t, msgs_s, "{spec}: message counts diverged");
        assert_eq!(retrans, 0);
        assert!(bytes_t.iter().sum::<u64>() > 0, "{spec}: no traffic");
    }
}

#[test]
fn steady_state_rounds_are_allocation_free_on_the_hot_path() {
    // The decode-into / frame-pool contract at the system level: after
    // a warmup run has filled the thread-local frame pool and sized
    // every machine's scratch, a whole repeat run (threads = 1, so all
    // work stays on this thread) performs zero pool misses and zero
    // allocating dense decodes.  A regression that reverts a codec to
    // its allocating `decode`, or leaks frame buffers past the pool,
    // trips this.
    let graph = Arc::new(Graph::ring(6));
    for spec in ["identity", "rand_k:0.1", "rand_k:0.1:values", "top_k:0.1",
                 "qsgd:4", "sign", "low_rank:2", "ef+top_k:0.1"] {
        let alg = cecl_codec(spec);
        let _ = simulated_run(&alg, &graph, 23, 3, LinkSpec::Ideal,
                              RoundPolicy::Sync);
        reset_hotpath_counters();
        let _ = simulated_run(&alg, &graph, 23, 3, LinkSpec::Ideal,
                              RoundPolicy::Sync);
        let (pool_misses, decode_allocs) = hotpath_counters();
        assert_eq!(
            (pool_misses, decode_allocs),
            (0, 0),
            "{spec}: steady-state rounds touched the allocator \
             (pool misses, dense decodes)"
        );
    }
}

#[test]
fn identity_codec_reproduces_ecl_byte_counts_exactly() {
    // C-ECL with the identity codec ships dense frames through the
    // codec path; its byte counts must equal the uncompressed ECL's
    // dense wire on both engines.
    let graph = Arc::new(Graph::ring(6));
    let ecl = AlgorithmSpec::Ecl { theta: 1.0 };
    let ident = cecl_codec("identity");
    let (bytes_ecl, msgs_ecl) = threaded_bytes(&ecl, &graph, 5, 4);
    let (bytes_id, msgs_id) = threaded_bytes(&ident, &graph, 5, 4);
    assert_eq!(bytes_ecl, bytes_id, "identity codec != ECL bytes");
    assert_eq!(msgs_ecl, msgs_id);
    let (bytes_sim, _, _) =
        simulated_bytes(&ident, &graph, 5, 4, LinkSpec::Ideal);
    assert_eq!(bytes_ecl, bytes_sim);
    // 4 bytes per coordinate per directed edge per round, exactly.
    let d = exchange_manifest().d_pad as u64;
    assert_eq!(bytes_id[0], 4 * d * 2 * 4); // 2 neighbors × 4 rounds
}

#[test]
fn values_only_wire_halves_randk_bytes() {
    let graph = Arc::new(Graph::ring(4));
    let (explicit, _) = threaded_bytes(&cecl_codec("rand_k:0.3"), &graph, 9, 3);
    let (values, _) =
        threaded_bytes(&cecl_codec("rand_k:0.3:values"), &graph, 9, 3);
    // Same shared-seed masks ⇒ exactly half the bytes per node.
    for (e, v) in explicit.iter().zip(&values) {
        assert_eq!(*e, 2 * v, "values-only is not half of explicit");
    }
}

#[test]
fn codec_runs_replay_bit_identically_under_lossy_links() {
    // Quantized and error-feedback codecs through the full simulated
    // stack (drops + retransmits + stragglers): deterministic replay,
    // nonzero traffic, finite accuracy — a retransmitted frame never
    // aborts the run.
    let graph = Graph::ring(6);
    for spec in ["rand_k:0.2:values", "qsgd:4", "ef+top_k:0.1", "sign"] {
        let exp = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: cecl_codec(spec),
            epochs: 4,
            nodes: 6,
            train_per_node: 20,
            test_size: 20,
            local_steps: 2,
            eta: 0.1,
            eval_every: 1,
            seed: 17,
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Lossy {
                    latency_us: 100,
                    mbit_per_sec: 50.0,
                    drop_p: 0.25,
                },
                stragglers: vec![(2, 2.0)],
                ..SimConfig::default()
            }),
            ..Default::default()
        };
        let a = run_simulated_native(&exp, &graph).unwrap();
        let b = run_simulated_native(&exp, &graph).unwrap();
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits(),
                   "{spec}: accuracy replay");
        assert_eq!(a.total_bytes, b.total_bytes, "{spec}: bytes replay");
        assert_eq!(a.retransmit_bytes, b.retransmit_bytes, "{spec}");
        assert!(a.total_bytes > 0 && a.retransmit_bytes > 0, "{spec}");
        assert!(a.final_accuracy.is_finite(), "{spec}");
    }
}

#[test]
fn deterministic_replay_every_algorithm_two_link_models() {
    let algs = [
        AlgorithmSpec::Sgd,
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::Ecl { theta: 1.0 },
        AlgorithmSpec::CEcl {
            k_frac: 0.2,
            theta: 1.0,
            dense_first_epoch: false,
        },
        AlgorithmSpec::NaiveCEcl { k_frac: 0.2, theta: 1.0 },
        AlgorithmSpec::PowerGossip { iters: 2 },
    ];
    let links = [
        LinkSpec::Constant { latency_us: 200 },
        LinkSpec::Lossy {
            latency_us: 200,
            mbit_per_sec: 50.0,
            drop_p: 0.1,
        },
    ];
    let graph = Graph::ring(4);
    for alg in &algs {
        for link in &links {
            // SGD collapses to a single node; a straggler entry for
            // node 1 would be out of range there.
            let stragglers = if alg.is_decentralized() {
                vec![(1, 2.0)]
            } else {
                Vec::new()
            };
            let spec = ExperimentSpec {
                dataset: "tiny".into(),
                algorithm: alg.clone(),
                epochs: 2,
                nodes: 4,
                train_per_node: 20,
                test_size: 40,
                local_steps: 2,
                eta: 0.1,
                eval_every: 1,
                seed: 9,
                exec: ExecMode::Simulated(SimConfig {
                    link: link.clone(),
                    stragglers,
                    ..SimConfig::default()
                }),
                ..Default::default()
            };
            let a = run_simulated_native(&spec, &graph).unwrap();
            let b = run_simulated_native(&spec, &graph).unwrap();
            let label = format!("{} / {}", alg.name(), link.name());
            assert_eq!(
                a.final_accuracy.to_bits(),
                b.final_accuracy.to_bits(),
                "{label}: accuracy"
            );
            assert_eq!(a.total_bytes, b.total_bytes, "{label}: bytes");
            assert_eq!(
                a.retransmit_bytes, b.retransmit_bytes,
                "{label}: retransmits"
            );
            assert_eq!(a.sim_time_secs, b.sim_time_secs, "{label}: clock");
            assert_eq!(
                a.history.records, b.history.records,
                "{label}: history"
            );
            assert_eq!(a.history.records.len(), 2, "{label}: eval points");
            assert!(a.sim_time_secs.unwrap() > 0.0, "{label}: clock ran");
        }
    }
}

#[test]
fn lossy_link_never_undercounts_bytes() {
    let graph = Graph::ring(6);
    let base = ExperimentSpec {
        dataset: "tiny".into(),
        algorithm: AlgorithmSpec::CEcl {
            k_frac: 0.3,
            theta: 1.0,
            dense_first_epoch: false,
        },
        epochs: 3,
        nodes: 6,
        train_per_node: 20,
        test_size: 20,
        local_steps: 2,
        eta: 0.1,
        eval_every: 3,
        seed: 13,
        ..Default::default()
    };
    let ideal = {
        let mut s = base.clone();
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Bandwidth {
                latency_us: 100,
                mbit_per_sec: 50.0,
            },
            ..SimConfig::default()
        });
        run_simulated_native(&s, &graph).unwrap()
    };
    let lossy = {
        let mut s = base.clone();
        s.exec = ExecMode::Simulated(SimConfig {
            link: LinkSpec::Lossy {
                latency_us: 100,
                mbit_per_sec: 50.0,
                drop_p: 0.3,
            },
            ..SimConfig::default()
        });
        run_simulated_native(&s, &graph).unwrap()
    };
    // The protocol's payload traffic is link-independent...
    assert_eq!(lossy.total_bytes, ideal.total_bytes);
    // ...drops only ever ADD retransmitted bytes (never under-count)...
    assert!(
        lossy.total_bytes + lossy.retransmit_bytes >= ideal.total_bytes
    );
    // ...and with p=0.3 over this much traffic they certainly happen,
    // stretching the virtual clock.
    assert!(lossy.retransmit_bytes > 0, "expected retransmissions");
    assert!(lossy.sim_time_secs.unwrap() > ideal.sim_time_secs.unwrap());
    assert_eq!(ideal.retransmit_bytes, 0);
}

#[test]
fn native_sim_learns_above_chance() {
    // 8-node ring, C-ECL(10%) on the softmax backend: with 40 local
    // steps it must clear random accuracy (0.1) decisively.
    let graph = Graph::ring(8);
    let spec = ExperimentSpec {
        dataset: "tiny".into(),
        algorithm: AlgorithmSpec::CEcl {
            k_frac: 0.1,
            theta: 1.0,
            dense_first_epoch: true,
        },
        epochs: 4,
        nodes: 8,
        train_per_node: 100,
        test_size: 100,
        local_steps: 2,
        eta: 0.1,
        eval_every: 2,
        seed: 3,
        exec: ExecMode::Simulated(SimConfig::default()),
        ..Default::default()
    };
    let r = run_simulated_native(&spec, &graph).unwrap();
    // Chance is 0.10 (10 balanced classes); the margin is deliberately
    // modest — this is a learning-signal smoke check, not a benchmark.
    assert!(
        r.final_accuracy > 0.13,
        "accuracy {} not above chance",
        r.final_accuracy
    );
    // Accuracy trajectory is recorded against the virtual clock.
    let times: Vec<f64> = r
        .history
        .records
        .iter()
        .map(|rec| rec.sim_time_secs)
        .collect();
    assert!(times.windows(2).all(|w| w[0] < w[1]), "clock not monotone");
    assert!(r.history.time_to_accuracy(0.0).is_some());
}

#[test]
fn ring_512_cecl_completes_and_reports_time_to_accuracy() {
    // The acceptance run: 512 nodes in a single process — impossible
    // with thread-per-node — under a bandwidth-limited link with one
    // straggler, replayed bit-identically.
    let graph = Graph::ring(512);
    let spec = ExperimentSpec {
        dataset: "tiny".into(),
        algorithm: AlgorithmSpec::CEcl {
            k_frac: 0.1,
            theta: 1.0,
            dense_first_epoch: false,
        },
        epochs: 2,
        nodes: 512,
        train_per_node: 20,
        test_size: 50,
        local_steps: 2,
        eta: 0.1,
        eval_every: 2,
        seed: 1,
        exec: ExecMode::Simulated(SimConfig {
            link: LinkSpec::Bandwidth {
                latency_us: 200,
                mbit_per_sec: 100.0,
            },
            stragglers: vec![(7, 3.0)],
            ..SimConfig::default()
        }),
        ..Default::default()
    };
    let r = run_simulated_native(&spec, &graph).unwrap();
    assert_eq!(r.history.records.len(), 1); // eval at epoch 2 only
    let sim_secs = r.sim_time_secs.expect("virtual clock");
    assert!(sim_secs > 0.0);
    assert!(r.total_bytes > 0);
    assert!(r.final_accuracy.is_finite());
    // Time-to-accuracy is reportable (target 0 ⇒ first eval qualifies).
    let (epoch, t2a) = r.history.time_to_accuracy(0.0).unwrap();
    assert_eq!(epoch, 2);
    assert!(t2a > 0.0 && t2a <= sim_secs);
    // Deterministic replay at scale.
    let r2 = run_simulated_native(&spec, &graph).unwrap();
    assert_eq!(r.final_accuracy.to_bits(), r2.final_accuracy.to_bits());
    assert_eq!(r.total_bytes, r2.total_bytes);
    assert_eq!(r.sim_time_secs, r2.sim_time_secs);
}

#[test]
fn sync_trajectory_bit_identical_to_pre_refactor_blocking_schedule() {
    // The `--rounds sync` pin: the per-edge-clock engine under
    // RoundPolicy::Sync must replay the EXACT trajectory of the
    // blocking thread-per-node schedule (which is, verbatim, the
    // pre-async bulk-synchronous driver) — final parameters
    // bit-identical, not approximately equal, even with nonzero link
    // latency reordering deliveries across nodes.
    let graph = Arc::new(Graph::ring(5));
    for alg in [
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::PowerGossip { iters: 2 },
    ] {
        let (bytes_t, msgs_t, ws_t) = threaded_run(&alg, &graph, 41, 4);
        for link in [
            LinkSpec::Ideal,
            LinkSpec::Constant { latency_us: 200 },
        ] {
            let (bytes_s, msgs_s, _, ws_s) = simulated_run(
                &alg, &graph, 41, 4, link.clone(), RoundPolicy::Sync,
            );
            assert_eq!(bytes_t, bytes_s, "{}: bytes", alg.name());
            assert_eq!(msgs_t, msgs_s, "{}: messages", alg.name());
            assert_eq!(
                ws_t, ws_s,
                "{} on {}: sync trajectory diverged from the blocking \
                 schedule",
                alg.name(),
                link.name()
            );
        }
    }
}

#[test]
fn acceptance_64_node_ring_async_straggler_beats_sync() {
    // The PR's acceptance scenario at full scale: 64-node ring, one 8×
    // straggler, latency-dominated links.  async:2 must reach the
    // target accuracy in measurably less simulated time than sync,
    // with the staleness bound holding and replay still bit-exact.
    let run = |rounds: RoundPolicy| {
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: AlgorithmSpec::CEcl {
                k_frac: 0.1,
                theta: 1.0,
                dense_first_epoch: false,
            },
            epochs: 4,
            nodes: 64,
            train_per_node: 40,
            test_size: 40,
            local_steps: 2,
            eta: 0.1,
            eval_every: 1,
            seed: 29,
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Constant { latency_us: 30_000 },
                compute_ns_per_step: 1_000_000,
                stragglers: vec![(11, 8.0)],
                ..SimConfig::default()
            }),
            rounds,
            ..Default::default()
        };
        run_simulated_native(&spec, &Graph::ring(64)).unwrap()
    };
    let sync = run(RoundPolicy::Sync);
    let async_ = run(RoundPolicy::Async { max_staleness: 2 });
    assert_eq!(sync.max_staleness, 0, "sync must never lag");
    assert!(async_.max_staleness >= 1, "the straggler's edges must lag");
    assert!(async_.max_staleness <= 2, "staleness bound violated");
    // Both complete all rounds: identical payload byte accounting.
    assert_eq!(sync.total_bytes, async_.total_bytes);
    let (ts, ta) = (
        sync.sim_time_secs.unwrap(),
        async_.sim_time_secs.unwrap(),
    );
    assert!(
        ta < 0.9 * ts,
        "async {ta}s not measurably below sync {ts}s"
    );
    let t2a_sync = sync.history.time_to_accuracy(0.0).unwrap().1;
    let t2a_async = async_.history.time_to_accuracy(0.0).unwrap().1;
    assert!(
        t2a_async < t2a_sync,
        "t2a async {t2a_async}s !< sync {t2a_sync}s"
    );
    // Determinism survives the async scheduler.
    let replay = run(RoundPolicy::Async { max_staleness: 2 });
    assert_eq!(replay.final_accuracy.to_bits(),
               async_.final_accuracy.to_bits());
    assert_eq!(replay.sim_time_secs, async_.sim_time_secs);
    assert_eq!(replay.max_staleness, async_.max_staleness);
}

#[test]
fn heterogeneous_edge_links_with_async_rounds() {
    // Satellite: per-edge LinkModel parameters through SimConfig.  One
    // slow edge in a 16-node ring; sync throttles the whole lockstep
    // ring through it, async:3 confines the damage to that edge.
    let run = |rounds: RoundPolicy, slow_edge: bool| {
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: AlgorithmSpec::CEcl {
                k_frac: 0.2,
                theta: 1.0,
                dense_first_epoch: false,
            },
            epochs: 4,
            nodes: 16,
            train_per_node: 40,
            test_size: 40,
            local_steps: 2,
            eta: 0.1,
            eval_every: 4,
            seed: 33,
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Constant { latency_us: 100 },
                edge_links: if slow_edge {
                    vec![(3, LinkSpec::Constant { latency_us: 5_000 })]
                } else {
                    Vec::new()
                },
                compute_ns_per_step: 1_000_000,
                ..SimConfig::default()
            }),
            rounds,
            ..Default::default()
        };
        run_simulated_native(&spec, &Graph::ring(16)).unwrap()
    };
    let sync_slow = run(RoundPolicy::Sync, true);
    let async_slow = run(RoundPolicy::Async { max_staleness: 3 }, true);
    let sync_fast = run(RoundPolicy::Sync, false);
    // The slow edge costs sync time...
    assert!(
        sync_slow.sim_time_secs.unwrap() > sync_fast.sim_time_secs.unwrap()
    );
    // ...async hides it within the staleness budget.
    assert!(
        async_slow.sim_time_secs.unwrap() < sync_slow.sim_time_secs.unwrap(),
        "async {:?} !< sync {:?}",
        async_slow.sim_time_secs,
        sync_slow.sim_time_secs
    );
    assert!(async_slow.max_staleness >= 1);
    assert!(async_slow.max_staleness <= 3);
    assert_eq!(sync_slow.total_bytes, async_slow.total_bytes);
}

#[test]
fn compression_wins_virtual_time_on_slow_links() {
    // The point of the whole exercise: on a bandwidth-limited link,
    // C-ECL(10%) finishes the same number of rounds in less virtual
    // time than uncompressed ECL (smaller messages serialize faster).
    let graph = Graph::ring(6);
    let run = |alg: AlgorithmSpec| {
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: alg,
            epochs: 2,
            nodes: 6,
            train_per_node: 20,
            test_size: 20,
            local_steps: 2,
            eta: 0.1,
            eval_every: 2,
            seed: 21,
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Bandwidth {
                    latency_us: 100,
                    // Slow enough that serialization dominates compute.
                    mbit_per_sec: 1.0,
                },
                compute_ns_per_step: 100_000,
                ..SimConfig::default()
            }),
            ..Default::default()
        };
        run_simulated_native(&spec, &graph).unwrap()
    };
    let ecl = run(AlgorithmSpec::Ecl { theta: 1.0 });
    let cecl = run(AlgorithmSpec::CEcl {
        k_frac: 0.1,
        theta: 1.0,
        dense_first_epoch: false,
    });
    assert!(cecl.total_bytes < ecl.total_bytes / 2);
    assert!(
        cecl.sim_time_secs.unwrap() < ecl.sim_time_secs.unwrap(),
        "C-ECL {}s vs ECL {}s",
        cecl.sim_time_secs.unwrap(),
        ecl.sim_time_secs.unwrap()
    );
}

#[test]
fn low_rank_codec_meters_powergossip_bytes_end_to_end() {
    // Acceptance pin: `--codec low_rank:2` meters exactly the bytes of
    // sync PowerGossip at rank 2 — same graph, same schedule, so equal
    // per-round-per-neighbor wire cost means equal totals.
    let graph = Graph::ring(6);
    let run = |alg: AlgorithmSpec| {
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: alg,
            epochs: 2,
            nodes: 6,
            train_per_node: 20,
            test_size: 20,
            local_steps: 2,
            eta: 0.1,
            eval_every: 2,
            seed: 55,
            exec: ExecMode::Simulated(SimConfig::default()),
            ..Default::default()
        };
        run_simulated_native(&spec, &graph).unwrap()
    };
    let pg = run(AlgorithmSpec::PowerGossip { iters: 2 });
    let lr = run(cecl_codec("low_rank:2"));
    assert!(pg.total_bytes > 0, "PowerGossip sent nothing");
    assert_eq!(
        pg.total_bytes, lr.total_bytes,
        "low_rank:2 must meter sync PowerGossip(2)'s bytes"
    );
    assert!(lr.final_accuracy.is_finite());
}

#[test]
fn powergossip_async_rounds_complete_bounded_and_replay() {
    // The tentpole: PowerGossip under `--rounds async:<s>` on the
    // virtual-time engine.  One 6x straggler plus a slow edge forces
    // conversations to straddle rounds; the run must complete, actually
    // use (and never exceed) the staleness budget, replay
    // bit-identically, and beat sync to the finish line.
    let graph = Graph::ring(8);
    let run = |rounds: RoundPolicy| {
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: AlgorithmSpec::PowerGossip { iters: 2 },
            epochs: 4,
            nodes: 8,
            train_per_node: 40,
            test_size: 40,
            local_steps: 2,
            eta: 0.1,
            eval_every: 4,
            seed: 13,
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Constant { latency_us: 10_000 },
                edge_links: vec![(2, LinkSpec::Constant {
                    latency_us: 40_000,
                })],
                compute_ns_per_step: 4_000_000,
                stragglers: vec![(5, 6.0)],
                ..SimConfig::default()
            }),
            rounds,
            ..Default::default()
        };
        run_simulated_native(&spec, &graph).unwrap()
    };
    let sync = run(RoundPolicy::Sync);
    assert_eq!(sync.max_staleness, 0, "sync PowerGossip must never lag");
    let policy = RoundPolicy::Async { max_staleness: 2 };
    let a = run(policy);
    let b = run(policy);
    assert!(a.max_staleness >= 1,
            "straggler/slow-edge conversations must actually straddle");
    assert!(a.max_staleness <= 2, "staleness bound violated");
    assert!(a.final_accuracy.is_finite());
    assert!(a.total_bytes > 0);
    // Deterministic replay, bit for bit.
    assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
    assert_eq!(a.total_bytes, b.total_bytes);
    assert_eq!(a.sim_time_secs, b.sim_time_secs);
    assert_eq!(a.max_staleness, b.max_staleness);
    // Async hides the straggler behind the staleness budget.
    assert!(
        a.sim_time_secs.unwrap() < sync.sim_time_secs.unwrap(),
        "async PG {:?} !< sync PG {:?}",
        a.sim_time_secs,
        sync.sim_time_secs
    );
}

#[test]
fn rival_codecs_meter_identical_bytes_and_trajectories_on_both_engines() {
    // CHOCO-SGD and LEAD through the same cross-engine contract as
    // C-ECL: for every rival × codec row (parsed via the CLI grammar,
    // so `choco:...`/`lead:...` specs are exercised end to end), the
    // threaded bus and the virtual-time engine account identical
    // first-copy bytes per node AND land on bit-identical parameters
    // under sync rounds — even with link latency reordering deliveries.
    let graph = Arc::new(Graph::ring(5));
    for spec in ["choco:rand_k:0.1", "choco:qsgd:4", "choco:ef+top_k:0.1",
                 "lead:rand_k:0.1", "lead:qsgd:4", "lead:ef+top_k:0.01"] {
        let alg = AlgorithmSpec::parse(spec).unwrap();
        let (bytes_t, msgs_t, ws_t) = threaded_run(&alg, &graph, 61, 3);
        assert!(bytes_t.iter().sum::<u64>() > 0, "{spec}: no traffic");
        for link in [LinkSpec::Ideal, LinkSpec::Constant { latency_us: 200 }] {
            let (bytes_s, msgs_s, retrans, ws_s) = simulated_run(
                &alg, &graph, 61, 3, link, RoundPolicy::Sync,
            );
            assert_eq!(bytes_t, bytes_s, "{spec}: per-node bytes diverged");
            assert_eq!(msgs_t, msgs_s, "{spec}: message counts diverged");
            assert_eq!(retrans, 0, "{spec}: lossless links never retransmit");
            assert_eq!(ws_t, ws_s, "{spec}: sync trajectory diverged");
        }
    }
}

#[test]
fn choco_identity_is_dpsgd_on_both_engines() {
    // Exact-gossip degeneration: CHOCO-SGD with the identity codec IS
    // D-PSGD — exact replicas and γ = τ = 1 collapse the consensus
    // step onto the Metropolis–Hastings fold.  Pinned bit-exactly on
    // the threaded bus and through the virtual-time engine.
    let graph = Arc::new(Graph::ring(5));
    let choco = AlgorithmSpec::Choco { codec: CodecSpec::Identity };
    let (_, msgs_d, ws_dpsgd) =
        threaded_run(&AlgorithmSpec::DPsgd, &graph, 19, 4);
    let (_, msgs_c, ws_choco_t) = threaded_run(&choco, &graph, 19, 4);
    assert_eq!(msgs_d, msgs_c, "both are one-message-per-neighbor-per-round");
    assert_eq!(ws_dpsgd, ws_choco_t, "threaded CHOCO+identity != D-PSGD");
    let (_, _, _, ws_choco_s) = simulated_run(
        &choco,
        &graph,
        19,
        4,
        LinkSpec::Constant { latency_us: 150 },
        RoundPolicy::Sync,
    );
    assert_eq!(ws_dpsgd, ws_choco_s, "simulated CHOCO+identity != D-PSGD");
}

#[test]
fn rival_machines_complete_churn_matrix_and_replay() {
    // The PR-5 churn matrix extended over the rival machines: 16-node
    // ring under `random:0.05` edge churn with short slots, CHOCO-SGD
    // and LEAD, sync and async:2 rounds.  Every cell must complete
    // without panics, surface real lifecycle transitions, respect the
    // staleness bound over live edges only, and replay bit-identically
    // — churn events and drops included.
    use cecl::graph::ChurnSchedule;

    let graph = Graph::ring(16);
    let algs = [
        AlgorithmSpec::Choco {
            codec: CodecSpec::parse("rand_k:0.1").unwrap(),
        },
        AlgorithmSpec::Lead { codec: CodecSpec::Qsgd { bits: 4 } },
    ];
    let policies =
        [RoundPolicy::Sync, RoundPolicy::Async { max_staleness: 2 }];
    for alg in &algs {
        for &rounds in &policies {
            let mut churn = ChurnSchedule::new();
            churn.random_edge_churn_with_slot(0.05, 7, 500_000);
            let spec = ExperimentSpec {
                dataset: "tiny".into(),
                algorithm: alg.clone(),
                epochs: 3,
                nodes: 16,
                train_per_node: 40,
                test_size: 40,
                local_steps: 2,
                eta: 0.1,
                eval_every: 3,
                seed: 29,
                exec: ExecMode::Simulated(SimConfig {
                    link: LinkSpec::Constant { latency_us: 200 },
                    compute_ns_per_step: 500_000,
                    churn,
                    ..SimConfig::default()
                }),
                rounds,
                ..Default::default()
            };
            let a = run_simulated_native(&spec, &graph).unwrap_or_else(|e| {
                panic!(
                    "{} / {}: churn run failed: {e}",
                    alg.name(),
                    rounds.name()
                )
            });
            assert!(
                a.edges_churned > 0,
                "{} / {}: no lifecycle transitions at 5%/slot",
                alg.name(),
                rounds.name()
            );
            assert!(
                a.max_staleness <= rounds.staleness(),
                "{} / {}: staleness {} exceeds bound {}",
                alg.name(),
                rounds.name(),
                a.max_staleness,
                rounds.staleness()
            );
            assert!(a.final_accuracy.is_finite());
            assert!(a.total_bytes > 0);
            let b = run_simulated_native(&spec, &graph).unwrap();
            assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
            assert_eq!(a.total_bytes, b.total_bytes);
            assert_eq!(a.edges_churned, b.edges_churned);
            assert_eq!(a.frames_dropped_by_churn, b.frames_dropped_by_churn);
            assert_eq!(a.sim_time_secs, b.sim_time_secs);
        }
    }
}

#[test]
fn churn_64_node_matrix_completes_for_all_algorithms_and_policies() {
    // The PR's acceptance run: a 64-node ring under `random:0.05` edge
    // churn (short slots so dozens of lifecycle transitions land inside
    // the run) for C-ECL, D-PSGD, and PowerGossip, under both sync and
    // async:2 rounds.  Every combination must complete without panics,
    // enforce the staleness bound over live edges only, surface real
    // churn counters, and replay bit-identically.
    use cecl::graph::ChurnSchedule;

    let graph = Graph::ring(64);
    let algs = [
        AlgorithmSpec::CEcl {
            k_frac: 0.1,
            theta: 1.0,
            dense_first_epoch: false,
        },
        AlgorithmSpec::DPsgd,
        AlgorithmSpec::PowerGossip { iters: 2 },
    ];
    let policies = [RoundPolicy::Sync, RoundPolicy::Async { max_staleness: 2 }];
    for alg in &algs {
        for &rounds in &policies {
            let mut churn = ChurnSchedule::new();
            // 5% per edge per 500 us slot; rounds tick every ~1.2 ms,
            // so edges flap many times over the run.
            churn.random_edge_churn_with_slot(0.05, 7, 500_000);
            let spec = ExperimentSpec {
                dataset: "tiny".into(),
                algorithm: alg.clone(),
                epochs: 3,
                nodes: 64,
                train_per_node: 40,
                test_size: 40,
                local_steps: 2,
                eta: 0.1,
                eval_every: 3,
                seed: 29,
                exec: ExecMode::Simulated(SimConfig {
                    link: LinkSpec::Constant { latency_us: 200 },
                    compute_ns_per_step: 500_000,
                    churn,
                    ..SimConfig::default()
                }),
                rounds,
                ..Default::default()
            };
            let a = run_simulated_native(&spec, &graph).unwrap_or_else(|e| {
                panic!("{} / {}: churn run failed: {e}", alg.name(),
                       rounds.name())
            });
            assert!(
                a.edges_churned > 0,
                "{} / {}: no lifecycle transitions at 5%/slot",
                alg.name(),
                rounds.name()
            );
            assert!(
                a.max_staleness <= rounds.staleness(),
                "{} / {}: staleness {} exceeds bound {}",
                alg.name(),
                rounds.name(),
                a.max_staleness,
                rounds.staleness()
            );
            assert!(a.final_accuracy.is_finite());
            assert!(a.total_bytes > 0);
            // Bit-identical replay, churn events and drops included.
            let b = run_simulated_native(&spec, &graph).unwrap();
            assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
            assert_eq!(a.total_bytes, b.total_bytes);
            assert_eq!(a.edges_churned, b.edges_churned);
            assert_eq!(a.frames_dropped_by_churn, b.frames_dropped_by_churn);
            assert_eq!(a.sim_time_secs, b.sim_time_secs);
        }
    }
}

#[test]
fn node_leave_mid_round_drains_in_flight_frames_as_metered_drops() {
    // The lifecycle satellite at the engine level: node 1 of a ring(4)
    // leaves while its round-0 frames are in flight (compute 100 us,
    // latency 50 us, leave at 120 us).  The frames drain as typed churn
    // drops, the byte meter stays byte-exact (sends are first-copy
    // metered whether or not delivery happens), and everyone else
    // finishes the run.
    use cecl::graph::ChurnSchedule;

    let graph = Graph::ring(4);
    let run = |leave: bool| {
        let mut churn = ChurnSchedule::new();
        if leave {
            churn.add_node_leave(1, 120_000);
        }
        let spec = ExperimentSpec {
            dataset: "tiny".into(),
            algorithm: AlgorithmSpec::CEcl {
                k_frac: 0.5,
                theta: 1.0,
                dense_first_epoch: false,
            },
            epochs: 2,
            nodes: 4,
            train_per_node: 20,
            test_size: 20,
            local_steps: 2,
            eta: 0.1,
            eval_every: 2,
            seed: 17,
            exec: ExecMode::Simulated(SimConfig {
                link: LinkSpec::Constant { latency_us: 50 },
                compute_ns_per_step: 50_000,
                churn,
                ..SimConfig::default()
            }),
            rounds: RoundPolicy::Sync,
            ..Default::default()
        };
        run_simulated_native(&spec, &graph).unwrap()
    };
    let churned = run(true);
    assert!(
        churned.frames_dropped_by_churn > 0,
        "in-flight frames of the leaver must drain as drops"
    );
    assert_eq!(churned.edges_churned, 2, "both incident edges die once");
    assert!(churned.final_accuracy.is_finite());
    // Byte-exactness: round-0 traffic is identical to the static run —
    // the leave lands after every round-0 frame was metered at send
    // time, dropped or not.  (Later rounds legitimately send less: the
    // leaver's edges are gone.)
    let static_run = run(false);
    assert!(
        churned.total_bytes < static_run.total_bytes,
        "a leaver must reduce total traffic ({} !< {})",
        churned.total_bytes,
        static_run.total_bytes
    );
}
