//! Parallel-engine acceptance suite: the partitioned conservative-PDES
//! loop (`SimConfig::threads > 1`) must be **bit-identical** to the
//! serial engine — same trajectories, same byte counters, same virtual
//! clock, same history — on every pinned replay:
//!
//! * codec × round-policy matrix: {identity, rand_k:0.1, ef+top_k:0.1}
//!   × {sync, async:2} on a latency ring;
//! * a `random:0.05` edge-churn row (typed churn drops included in the
//!   fingerprint);
//! * an 8192-node ring replay-determinism pin: serial twice (replay)
//!   and serial-vs-8-threads (partition invariance).

use std::sync::Arc;

use cecl::algorithms::{build_machine, AlgorithmSpec, BuildCtx, DualPath,
                       RoundPolicy};
use cecl::compress::CodecSpec;
use cecl::graph::{ChurnSchedule, Graph};
use cecl::model::DatasetManifest;
use cecl::sim::{simulate, LinkSpec, NodeSetup, NullLocal, Schedule,
                SimConfig, SimOutcome};
use cecl::util::rng::Pcg;

fn manifest() -> DatasetManifest {
    // d = (2*2*1 + 1) * 3 = 15 parameters.
    DatasetManifest::synthetic_linear("t", (2, 2, 1), 3, 2, 2)
}

fn ctx(node: usize, graph: &Arc<Graph>, seed: u64, rounds_per_epoch: usize,
       round_policy: RoundPolicy) -> BuildCtx {
    BuildCtx {
        node,
        graph: Arc::clone(graph),
        manifest: manifest(),
        seed,
        eta: 0.05,
        local_steps: 2,
        rounds_per_epoch,
        dual_path: DualPath::Native,
        runtime: None,
        round_policy,
    }
}

fn init_w(node: usize) -> Vec<f32> {
    let mut rng = Pcg::new(500 + node as u64);
    (0..manifest().d_pad).map(|_| rng.normal_f32()).collect()
}

/// Everything a run produces, reduced to exactly-comparable bits: the
/// virtual clock, every meter counter, final parameters, and the full
/// eval history.  Two runs are "bit-identical" iff their fingerprints
/// are equal.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    vtime_ns: u64,
    bytes_per_node: Vec<u64>,
    total_msgs: u64,
    retransmit_bytes: u64,
    edge_payload_bytes: Option<Vec<u64>>,
    churn_dropped_frames: u64,
    churn_dropped_bytes: u64,
    edges_churned: u64,
    max_staleness: usize,
    w_bits: Vec<Vec<u32>>,
    records: Vec<(usize, u64, u64, u64, u64, u64)>,
}

fn fingerprint(out: &SimOutcome, n: usize) -> Fingerprint {
    Fingerprint {
        vtime_ns: out.vtime_ns,
        bytes_per_node: (0..n).map(|i| out.meter.bytes_sent(i)).collect(),
        total_msgs: out.meter.total_msgs(),
        retransmit_bytes: out.meter.total_retransmit_bytes(),
        edge_payload_bytes: out.meter.edge_payload_bytes(),
        churn_dropped_frames: out.meter.churn_dropped_frames(),
        churn_dropped_bytes: out.meter.churn_dropped_bytes(),
        edges_churned: out.edges_churned,
        max_staleness: out.max_staleness,
        w_bits: out
            .w
            .iter()
            .map(|w| w.iter().map(|v| v.to_bits()).collect())
            .collect(),
        records: out
            .history
            .records
            .iter()
            .map(|r| {
                (
                    r.epoch,
                    r.mean_accuracy.to_bits(),
                    r.mean_loss.to_bits(),
                    r.train_loss.to_bits(),
                    r.cum_bytes_per_node.to_bits(),
                    r.sim_time_secs.to_bits(),
                )
            })
            .collect(),
    }
}

/// Build a fresh fleet and run it under `cfg`, returning the
/// fingerprint.  Fresh machines per call: state machines are stateful,
/// so every compared run starts from identical initial state.
fn run(alg: &AlgorithmSpec, graph: &Arc<Graph>, seed: u64, sched: &Schedule,
       policy: RoundPolicy, cfg: &SimConfig) -> Fingerprint {
    let setups: Vec<NodeSetup> = (0..graph.n())
        .map(|i| NodeSetup {
            machine: build_machine(
                alg,
                &ctx(i, graph, seed, sched.rounds_per_epoch, policy),
            )
            .unwrap(),
            local: Box::new(NullLocal),
            w: init_w(i),
        })
        .collect();
    let out = simulate(graph, cfg, seed, sched, setups, policy, false)
        .unwrap();
    fingerprint(&out, graph.n())
}

fn cecl_codec(spec: &str) -> AlgorithmSpec {
    AlgorithmSpec::CEclCodec {
        codec: CodecSpec::parse(spec).unwrap(),
        theta: 1.0,
        dense_first_epoch: false,
    }
}

#[test]
fn parallel_bit_identity_codec_policy_matrix() {
    // {identity, rand_k:0.1, ef+top_k:0.1} × {sync, async:2} on a
    // 12-node latency ring: 3 worker threads must reproduce the serial
    // run bit-for-bit — parameters, bytes, clock, history, staleness.
    let graph = Arc::new(Graph::ring(12));
    let sched = Schedule::new(2, 2, 2, 1);
    let serial = SimConfig {
        link: LinkSpec::Constant { latency_us: 200 },
        ..SimConfig::default()
    };
    let parallel = SimConfig { threads: 3, ..serial.clone() };
    for spec in ["identity", "rand_k:0.1", "ef+top_k:0.1"] {
        let alg = cecl_codec(spec);
        for policy in [
            RoundPolicy::Sync,
            RoundPolicy::Async { max_staleness: 2 },
        ] {
            let a = run(&alg, &graph, 33, &sched, policy, &serial);
            let b = run(&alg, &graph, 33, &sched, policy, &parallel);
            assert!(a.total_msgs > 0, "{spec}/{}: no traffic", policy.name());
            assert_eq!(
                a, b,
                "{spec}/{}: parallel diverged from serial", policy.name()
            );
        }
    }
}

#[test]
fn parallel_bit_identity_under_random_churn() {
    // The `random:0.05` rule churns edges i.i.d. per 10 ms slot.  Churn
    // is applied at window boundaries by the driver, so the partitioned
    // loop must see the exact same edge lifecycle — including in-flight
    // frames drained as typed churn drops — as the serial one.  Slow
    // virtual compute (10 ms/step) stretches the run across ~32 slots
    // so the rule actually fires (deterministically, seed-pinned).
    let graph = Arc::new(Graph::ring(10));
    let sched = Schedule::new(8, 2, 2, 4);
    let serial = SimConfig {
        link: LinkSpec::Constant { latency_us: 200 },
        compute_ns_per_step: 10_000_000,
        churn: ChurnSchedule::parse("random:0.05").unwrap(),
        ..SimConfig::default()
    };
    let parallel = SimConfig { threads: 4, ..serial.clone() };
    let alg = cecl_codec("rand_k:0.1");
    let a = run(&alg, &graph, 71, &sched, RoundPolicy::Sync, &serial);
    let b = run(&alg, &graph, 71, &sched, RoundPolicy::Sync, &parallel);
    assert!(a.edges_churned > 0, "random rule never churned an edge");
    assert_eq!(a, b, "parallel diverged from serial under random churn");
}

#[test]
fn ring_8k_replay_determinism_pin() {
    // Scale pin: an 8192-node ring (dense ECL exchange, null local
    // model) replays bit-identically serial-vs-serial AND
    // serial-vs-8-threads.  This is the acceptance test for the
    // calendar queue + pooled frames + partitioned loop at a size where
    // bucket-wheel rotation, pool recycling, and window batching all
    // actually engage.
    let n = 8192;
    let graph = Arc::new(Graph::ring(n));
    let sched = Schedule::new(1, 2, 1, 1);
    let serial = SimConfig {
        link: LinkSpec::Constant { latency_us: 100 },
        ..SimConfig::default()
    };
    let parallel = SimConfig { threads: 8, ..serial.clone() };
    let alg = AlgorithmSpec::Ecl { theta: 1.0 };
    let a = run(&alg, &graph, 4242, &sched, RoundPolicy::Sync, &serial);
    let b = run(&alg, &graph, 4242, &sched, RoundPolicy::Sync, &serial);
    assert_eq!(a, b, "8k serial replay is not deterministic");
    let c = run(&alg, &graph, 4242, &sched, RoundPolicy::Sync, &parallel);
    assert_eq!(a, c, "8k parallel diverged from serial");
    // 2 rounds × 2 neighbors per node, every message delivered.
    assert_eq!(a.total_msgs, (n as u64) * 2 * 2);
    assert!(a.vtime_ns > 0);
}
